"""Append the final roofline tables to EXPERIMENTS.md."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.perfmodel.report import load_records, roofline_table  # noqa: E402

ROOT = os.path.join(os.path.dirname(__file__), "..")
MARK = "## §Roofline — FINAL TABLES"


def main():
    out = [MARK, ""]
    for mesh, title in (("pod", "Single-pod (16x16 = 256 chips)"),
                        ("multipod", "Multi-pod (2x16x16 = 512 chips)")):
        recs = load_records(mesh=mesh)
        out += [f"### {title} — baseline variant", "",
                roofline_table(recs), ""]
    opt_dir = os.path.join(ROOT, "reports", "dryrun_opt")
    if os.path.isdir(opt_dir):
        recs = load_records(opt_dir, "pod")
        if recs:
            out += ["### Single-pod — optimized variant "
                    "(serving layout, decode cells)", "",
                    roofline_table(recs), ""]
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(path) as f:
        text = f.read()
    head = text.split(MARK)[0]
    with open(path, "w") as f:
        f.write(head + "\n".join(out))
    print("EXPERIMENTS.md finalized")


if __name__ == "__main__":
    main()
