"""Re-derive roofline records from cached HLO (no recompilation).

Used when the HLO cost model improves: reads the .hlo.zst cached next
to each dry-run JSON, re-runs `hlo_cost.analyze`, and rewrites the
roofline terms in place.
"""
import glob
import json
import os
import sys

import zstandard

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.perfmodel import hlo_cost, roofline as roof  # noqa: E402


def reanalyze(json_path: str) -> bool:
    hlo_path = json_path.replace(".json", ".hlo.zst")
    if not os.path.exists(hlo_path):
        return False
    with open(hlo_path, "rb") as f:
        text = zstandard.ZstdDecompressor().decompress(f.read()).decode()
    with open(json_path) as f:
        rec = json.load(f)
    parsed = hlo_cost.analyze(text)
    r = roof.make(rec["arch"], rec["shape"], rec["mesh"], rec["chips"],
                  cost={"flops": parsed["flops"],
                        "bytes accessed": parsed["bytes"]},
                  collectives=parsed, model_flops=rec["model_flops"],
                  bytes_per_device=rec["bytes_per_device"])
    rec.update(r.as_dict())
    rec["collectives"] = dict(bytes_by_op=parsed["bytes_by_op"],
                              counts=parsed["counts"],
                              total_bytes=parsed["total_bytes"])
    with open(json_path, "w") as f:
        json.dump(rec, f, indent=1)
    return True


def main():
    root = os.path.join(os.path.dirname(__file__), "..", "reports")
    pats = sys.argv[1:] or [os.path.join(root, "dryrun*", "*", "*.json")]
    n = 0
    for pat in pats:
        for p in sorted(glob.glob(pat)):
            if reanalyze(p):
                n += 1
    print(f"reanalyzed {n} records")


if __name__ == "__main__":
    main()
