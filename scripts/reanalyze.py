"""Re-derive roofline records from cached HLO (no recompilation).

Used when the HLO cost model improves: reads the .hlo.zst cached next
to each dry-run JSON, re-runs `hlo_cost.analyze`, and rewrites the
roofline terms in place.

``--list-benchmarks`` prints the registered benchmark entry points and
the report artifacts each one owns — the same single registry
(`benchmarks.registry`) that drives ``benchmarks/run.py``, so this
script and the runner always agree on what exists.

``--report perspectives [--preset P]`` re-renders the saved
three-perspective divergence ladder (``perspectives*.json``) as a
markdown table — reanalysis of the stored artifact, no simulation.
``--report cmd_oracle`` does the same for the command-level oracle
grid (``cmd_oracle.json``).
"""
import glob
import json
import os
import sys

try:                           # optional: only needed to re-read HLO blobs
    import zstandard
except ImportError:            # pragma: no cover - container without zstd
    zstandard = None

_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)


def reanalyze(json_path: str) -> bool:
    from repro.perfmodel import hlo_cost, roofline as roof

    hlo_path = json_path.replace(".json", ".hlo.zst")
    if not os.path.exists(hlo_path):
        return False
    if zstandard is None:
        raise SystemExit("reanalyze needs the 'zstandard' package")
    with open(hlo_path, "rb") as f:
        text = zstandard.ZstdDecompressor().decompress(f.read()).decode()
    with open(json_path) as f:
        rec = json.load(f)
    parsed = hlo_cost.analyze(text)
    r = roof.make(rec["arch"], rec["shape"], rec["mesh"], rec["chips"],
                  cost={"flops": parsed["flops"],
                        "bytes accessed": parsed["bytes"]},
                  collectives=parsed, model_flops=rec["model_flops"],
                  bytes_per_device=rec["bytes_per_device"])
    rec.update(r.as_dict())
    rec["collectives"] = dict(bytes_by_op=parsed["bytes_by_op"],
                              counts=parsed["counts"],
                              total_bytes=parsed["total_bytes"])
    with open(json_path, "w") as f:
        json.dump(rec, f, indent=1)
    return True


def list_benchmarks():
    """Print the benchmark registry with each one's report artifacts."""
    from benchmarks.registry import BENCHMARKS

    bench_dir = os.path.join(_ROOT, "reports", "benchmarks")
    for spec in BENCHMARKS.values():
        found = [os.path.basename(p) for pat in spec.reports
                 for p in sorted(glob.glob(os.path.join(bench_dir, pat)))]
        reports = ", ".join(found) if found else "(no reports on disk)"
        print(f"{spec.name:16s} {spec.description}")
        print(f"{'':16s}   -> {reports}")


def report(name: str):
    """Render a saved report artifact (``--report <name>``)."""
    if name == "perspectives":
        from benchmarks.perspectives import ladder_table

        preset = next((a.split("=", 1)[1] for a in sys.argv
                       if a.startswith("--preset=")), "ddr4_2666")
        print(ladder_table(preset=preset))
        return
    if name == "cmd_oracle":
        from benchmarks.cmd_oracle import oracle_table

        print(oracle_table())
        return
    raise SystemExit(
        f"unknown report {name!r}; one of: perspectives, cmd_oracle")


def main():
    if "--list-benchmarks" in sys.argv:
        list_benchmarks()
        return
    if "--report" in sys.argv:
        report(sys.argv[sys.argv.index("--report") + 1])
        return
    root = os.path.join(_ROOT, "reports")
    pats = sys.argv[1:] or [os.path.join(root, "dryrun*", "*", "*.json")]
    n = 0
    for pat in pats:
        for p in sorted(glob.glob(pat)):
            if reanalyze(p):
                n += 1
    print(f"reanalyzed {n} records")


if __name__ == "__main__":
    main()
