"""Cross-reference checker for the docs (CI docs job).

Fails (exit 1) if any of these are broken in docs/*.md or README.md:

* relative markdown links ``[text](path)``;
* repo paths like ``src/repro/core/dram.py`` or ``benchmarks/run.py``
  (globs with ``*`` allowed — they must match at least one file);
* dotted module references ``repro.x.y[.attr]`` — the longest module
  prefix must import and any attribute remainder must resolve;
* the module-map block in docs/ARCHITECTURE.md: every ``name.py`` /
  ``name/`` entry must exist under its section's directory.

Run:  PYTHONPATH=src python scripts/check_docs.py
"""
from __future__ import annotations

import glob
import importlib
import importlib.util
import os
import re
import sys

ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))
DOCS = [os.path.join(ROOT, "README.md"),
        *sorted(glob.glob(os.path.join(ROOT, "docs", "*.md")))]

_errors: list[str] = []


def err(doc: str, msg: str) -> None:
    _errors.append(f"{os.path.relpath(doc, ROOT)}: {msg}")


def check_links(doc: str, text: str) -> None:
    for m in re.finditer(r"\[[^\]]+\]\(([^)#\s]+)(?:#[^)]*)?\)", text):
        target = m.group(1)
        if target.startswith(("http://", "https://")):
            continue
        path = os.path.normpath(os.path.join(os.path.dirname(doc), target))
        if not os.path.exists(path):
            err(doc, f"broken link -> {target}")


def check_paths(doc: str, text: str) -> None:
    pat = r"(?<![\w/])((?:src|benchmarks|examples|scripts|tests|docs)/[\w/.*-]+)"
    for m in re.finditer(pat, text):
        rel = m.group(1).rstrip(".")
        matches = glob.glob(os.path.join(ROOT, rel))
        if not matches:
            err(doc, f"missing path -> {rel}")


def check_modules(doc: str, text: str) -> None:
    seen = set()
    for m in re.finditer(r"\brepro(?:\.\w+)+", text):
        name = m.group(0)
        if name in seen:
            continue
        seen.add(name)
        parts = name.split(".")
        for cut in range(len(parts), 0, -1):
            mod = ".".join(parts[:cut])
            try:
                found = importlib.util.find_spec(mod) is not None
            except ModuleNotFoundError:
                found = False
            if found:
                break
        else:
            err(doc, f"unresolvable module -> {name}")
            continue
        rest = parts[cut:]
        if rest:
            obj = importlib.import_module(mod)
            for attr in rest:
                if not hasattr(obj, attr):
                    err(doc, f"module {mod} has no attribute "
                             f"{'.'.join(rest)} (from {name})")
                    break
                obj = getattr(obj, attr)


def check_module_map(doc: str, text: str) -> None:
    """The first fenced block of ARCHITECTURE.md is the module map."""
    m = re.search(r"```\n(src/repro/.*?)```", text, re.S)
    if not m:
        err(doc, "module-map block not found")
        return
    current = None
    for line in m.group(1).splitlines():
        head = re.match(r"^(\S+?)/\s", line + " ")
        entry = re.match(r"^\s+([\w.]+(?:\.py|/))\s", line)
        if head and not line.startswith(" "):
            current = head.group(1)
            if not os.path.isdir(os.path.join(ROOT, current)):
                err(doc, f"module-map directory missing -> {current}")
        elif entry and current:
            path = os.path.join(ROOT, current, entry.group(1).rstrip("/"))
            if not (os.path.exists(path) or os.path.isdir(path)):
                err(doc, f"module-map entry missing -> "
                         f"{current}/{entry.group(1)}")


def main() -> int:
    sys.path.insert(0, os.path.join(ROOT, "src"))
    for doc in DOCS:
        with open(doc) as f:
            text = f.read()
        check_links(doc, text)
        check_paths(doc, text)
        check_modules(doc, text)
        if doc.endswith("ARCHITECTURE.md"):
            check_module_map(doc, text)
    for e in _errors:
        print(f"BROKEN  {e}")
    print(f"checked {len(DOCS)} docs: "
          f"{'FAIL' if _errors else 'all cross-references resolve'}")
    return 1 if _errors else 0


if __name__ == "__main__":
    sys.exit(main())
