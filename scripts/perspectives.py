"""Compute the three-perspective divergence report from the CLI.

Thin wrapper over `benchmarks.perspectives`: replays one telemetry-on
mix per correction-ladder stage, writes the divergence ladder
(``reports/benchmarks/perspectives_<preset>.json``) and the final
stage's Perfetto timeline, and prints the ladder table.

Usage:
    python scripts/perspectives.py [--full] [--preset=P] [--table]

``--table`` only re-renders the saved report (no simulation) — the
same path as ``scripts/reanalyze.py --report perspectives``.
"""
import os
import sys

_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)


def main():
    from benchmarks.perspectives import ladder_table, main as run

    preset = next((a.split("=", 1)[1] for a in sys.argv
                   if a.startswith("--preset=")), "ddr4_2666")
    if "--table" not in sys.argv:
        run(full="--full" in sys.argv, preset=preset)
    print(ladder_table(preset=preset))


if __name__ == "__main__":
    main()
