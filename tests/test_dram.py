"""Cycle-accurate DRAM model: DDR4 protocol-legality invariants.

We drive `dram.tick` directly with crafted queues and verify the state
machine respects the JEDEC timing set (the paper's premise is that the
memory simulator itself honors Verilog timings — the bugs live in the
interface; our DRAM model must therefore be timing-legal).
"""
import jax.numpy as jnp
import numpy as np

from repro.core import dram
from repro.core.dram import SchedulerPolicy
from repro.core.timing import DramParams

D = DramParams()
POL = SchedulerPolicy()


def mk_queue(entries):
    """entries: list of dicts(channel, fbank, row, is_write, arrival)."""
    q = dram.init_queue(D, POL)
    for i, e in enumerate(entries):
        c = e["channel"]
        q = dram.QueueState(
            valid=q.valid.at[c, i].set(1),
            is_write=q.is_write.at[c, i].set(int(e.get("is_write", 0))),
            arrival=q.arrival.at[c, i].set(e.get("arrival", 0)),
            issue_cycle=q.issue_cycle.at[c, i].set(0),
            fbank=q.fbank.at[c, i].set(e["fbank"]),
            row=q.row.at[c, i].set(e["row"]),
            is_chase=q.is_chase.at[c, i].set(0),
        )
    return q


def run_ticks(q, b, n, start=0):
    served = []
    for t in range(start, start + n):
        q, b, st = dram.tick(q, b, jnp.int32(t), dram=D, policy=POL,
                             tick2cpu_num=750, tick2cpu_den=1,
                             cpu_ps_per_clk=476)
        # TickStats is per-channel (C,); reduce to per-tick totals
        served.append((t, int(st.served_rd.sum()), int(st.served_wr.sum())))
    return q, b, served


def test_act_to_cas_respects_trcd():
    """A read to a closed row must wait tRCD after the ACT."""
    q = mk_queue([dict(channel=0, fbank=0, row=5)])
    b = dram.init_banks(D)
    q, b, served = run_ticks(q, b, 60)
    rd_ticks = [t for t, r, w in served if r > 0]
    assert len(rd_ticks) == 1
    # ACT issues at t=0; CAS legal at t=tRCD
    assert rd_ticks[0] == D.tRCD


def test_row_hit_is_immediate():
    q = mk_queue([dict(channel=0, fbank=0, row=5)])
    b = dram.init_banks(D)._replace(
        open_row=dram.init_banks(D).open_row.at[0, 0].set(5))
    q, b, served = run_ticks(q, b, 10)
    rd_ticks = [t for t, r, w in served if r > 0]
    assert rd_ticks[0] == 0


def test_row_miss_needs_pre_act_cas():
    """Conflict: open row 3, request row 5 -> PRE + tRP + ACT + tRCD."""
    b0 = dram.init_banks(D)
    b = b0._replace(open_row=b0.open_row.at[0, 0].set(3))
    q = mk_queue([dict(channel=0, fbank=0, row=5)])
    q, b, served = run_ticks(q, b, 80)
    rd_ticks = [t for t, r, w in served if r > 0]
    # PRE at 0, ACT at tRP, CAS at tRP + tRCD
    assert rd_ticks[0] == D.tRP + D.tRCD


def test_bus_serializes_cas():
    """Two row hits to different banks on one channel: the shared data
    bus forces >= tBL spacing between CAS grants."""
    b0 = dram.init_banks(D)
    open_row = b0.open_row.at[0, 0].set(1).at[0, 1].set(1)
    b = b0._replace(open_row=open_row)
    q = mk_queue([dict(channel=0, fbank=0, row=1),
                  dict(channel=0, fbank=1, row=1)])
    q, b, served = run_ticks(q, b, 20)
    rd_ticks = [t for t, r, w in served if r > 0]
    assert len(rd_ticks) == 2
    assert rd_ticks[1] - rd_ticks[0] >= D.tBL


def test_faw_limits_activation_rate():
    """>4 ACTs to one rank within tFAW must be delayed (tFAW window)."""
    q = mk_queue([dict(channel=0, fbank=i, row=7) for i in range(6)])
    b = dram.init_banks(D)
    q, b, served = run_ticks(q, b, 120)
    # collect ACT-equivalents: the first CAS per bank happened tRCD
    # after its ACT; reconstruct ACT times
    rd_ticks = sorted(t for t, r, w in served if r > 0)
    act_ticks = [t - D.tRCD for t in rd_ticks]
    # 5th activation must fall outside the first ACT's tFAW window
    assert act_ticks[4] >= act_ticks[0] + D.tFAW


def test_channels_are_independent():
    q = mk_queue([dict(channel=0, fbank=0, row=5),
                  dict(channel=3, fbank=0, row=9)])
    b = dram.init_banks(D)
    q, b, served = run_ticks(q, b, 40)
    # both channels serve at the same tick (no cross-channel coupling)
    assert max(r for _, r, _ in served) == 2


def test_refresh_blocks_rank():
    """At tREFI the rank refreshes; reads stall for tRFC."""
    b0 = dram.init_banks(D)
    # force refresh deadline to t=5 on rank 0 of channel 0
    b = b0._replace(next_ref=b0.next_ref.at[0, 0].set(5),
                    open_row=b0.open_row.at[0, 0].set(5))
    q = mk_queue([dict(channel=0, fbank=0, row=5, arrival=6)])
    q, b, served = run_ticks(q, b, 600)
    rd_ticks = [t for t, r, w in served if r > 0]
    # refresh closed the row at t=5; ACT cannot start before 5 + tRFC
    assert rd_ticks[0] >= 5 + D.tRFC + D.tRCD


def test_write_drain_hysteresis():
    """Writes are buffered until the high watermark, then drained."""
    entries = [dict(channel=0, fbank=i % 4, row=1, is_write=1)
               for i in range(POL.drain_hi + 2)]
    q = mk_queue(entries)
    b = dram.init_banks(D)
    q, b, served = run_ticks(q, b, 400)
    wr_total = sum(w for _, r, w in served)
    assert wr_total >= POL.drain_hi - POL.drain_lo  # drained a batch


def test_next_event_is_a_lower_bound():
    """Property: `dram.next_event` never reports a horizon past real
    work — for every channel, ticking the frozen state at any time
    strictly before the reported event grants nothing and moves no
    state (the event-horizon weave engine's correctness premise)."""
    from _proptest import forall

    tick_kw = dict(dram=D, policy=POL, tick2cpu_num=750, tick2cpu_den=1,
                   cpu_ps_per_clk=476)

    @forall(n_cases=12,
            case_seed=lambda rng: int(rng.integers(0, 1 << 30)))
    def prop(case_seed):
        rng = np.random.default_rng(case_seed)
        entries = [dict(channel=int(rng.integers(0, D.n_channels)),
                        fbank=int(rng.integers(0, D.banks_per_channel)),
                        row=int(rng.integers(0, 64)),
                        is_write=int(rng.integers(0, 2)),
                        arrival=int(rng.integers(0, 48)))
                   for _ in range(int(rng.integers(1, 9)))]
        q = mk_queue(entries)
        b = dram.init_banks(D)
        t0 = int(rng.integers(0, 40))
        q, b, _ = run_ticks(q, b, t0)          # a reachable mid-flight state
        end = t0 + 1 + int(rng.integers(1, 20000))
        ev = np.asarray(dram.next_event(q, b, jnp.int32(t0),
                                        jnp.int32(end), dram=D, policy=POL))
        assert ((ev > t0) & (ev <= end)).all()
        for c in range(D.n_channels):
            span = int(ev[c]) - t0
            probes = {int(ev[c]) - 1, t0 + 1 + int(rng.integers(0, span))}
            for tau in probes:
                if not t0 < tau < int(ev[c]):
                    continue
                q2, b2, st = dram.tick(q, b, jnp.int32(tau), **tick_kw)
                assert int(st.served_rd[c]) == int(st.served_wr[c]) == 0, \
                    (case_seed, c, tau, int(ev[c]))
                for name, x, y in zip(b._fields, b, b2):
                    np.testing.assert_array_equal(
                        np.asarray(x)[c], np.asarray(y)[c],
                        err_msg=f"banks.{name} moved before the horizon "
                                f"(ch {c}, t {tau} < ev {int(ev[c])})")
                for name, x, y in zip(q._fields, q, q2):
                    np.testing.assert_array_equal(
                        np.asarray(x)[c], np.asarray(y)[c],
                        err_msg=f"queue.{name} moved before the horizon "
                                f"(ch {c}, t {tau} < ev {int(ev[c])})")

    prop()
