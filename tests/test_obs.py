"""Three-perspective telemetry: zero-impact when off, exact when on.

The contract of `StageConfig.telemetry`:

* **off (default)** — the traced computation is the exact historical
  graph: every semantic output is bit-identical with the flag on vs
  off, on both weave engines;
* **on** — the ``tele_*`` planes are event-accounted inside
  `repro.core.dram.tick`, so the dense and event-horizon engines
  accumulate identical planes, and the histograms are exact: every
  served read lands in exactly one bucket of each latency histogram.

Plus unit coverage of the reduction / export / divergence layers
(`repro.obs`).
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dram, get_stage
from repro.core.platform import run_frontend
from repro.core.workload import MessFrontend
from repro.obs import (TELE_KEYS, collect, hist_edges, hist_percentiles,
                       spearman, summarize, to_json, to_perfetto,
                       validate_perfetto, window_series)
from repro.obs.perspectives import divergence, divergence_report
from repro.traces import assign_traces, split_cores
from repro.traces.frontend import TraceFrontend
from repro.traces.kernels import gups, stream

FAST = dict(windows=6, warmup=2)

#: view keys that must not move when telemetry turns on
SEMANTIC_VIEWS = ("sim_bw_gbs", "sim_lat_ns", "if_bw_gbs", "if_lat_ns",
                  "app_bw_gbs", "app_lat_ns", "chase_lat_ns",
                  "n_rd", "n_wr", "l_ir_final", "injected")


def mess(pace=8, wr=16):
    def build(cfg):
        fe = MessFrontend(jnp.int32(pace), jnp.int32(wr),
                          cfg.workload_config())
        return lambda: run_frontend(cfg, fe)

    return build


def solo(n=256):
    trace = stream(n=n)

    def build(cfg):
        return lambda: run_frontend(
            cfg, TraceFrontend(trace, cfg.workload_config()))

    build.full_budget = True          # MSHR-hot replay needs full budget
    return build


def mix(n=192):
    apps = [stream(n=n), gups(n=n)]

    def build(cfg):
        m = assign_traces(apps,
                          split_cores(2, cfg.workload_config().n_cores),
                          phase_offsets=None)
        return lambda: run_frontend(
            cfg, TraceFrontend(m, cfg.workload_config()))

    build.full_budget = True
    return build


def run_cell(stage, preset, frontend, weave, telemetry):
    cfg = get_stage(stage, preset=preset, weave=weave,
                    telemetry=telemetry, **FAST)
    if weave == "event" and getattr(frontend, "full_budget", False):
        cfg = dataclasses.replace(
            cfg, weave_events=cfg.clock().ticks_per_window_static)
    views, outs = jax.device_get(jax.jit(frontend(cfg))())
    return cfg, views, outs


# presets x weave engines x frontend kinds — the golden-grid subset
GRID = [
    ("10-delay-buffer", "ddr4_2666", mess()),
    ("04-model-correct", "ddr4_2666", solo()),
    ("10-delay-buffer", "ddr5_4800", mix()),
    ("01-baseline", "hbm2e", mix()),
]
_IDS = [f"{g[0]}-{g[1]}-{g[2].__qualname__.split('.')[0]}" for g in GRID]


@pytest.mark.parametrize("stage,preset,frontend", GRID, ids=_IDS)
def test_telemetry_off_and_on_agree(stage, preset, frontend):
    """One grid cell, both engines: (a) turning telemetry on changes no
    semantic output bit (off == seed graph by construction, so off-vs-on
    equality pins the on-path too); (b) the planes agree between the
    dense and event engines; (c) histogram totals equal served reads,
    per window."""
    planes = {}
    for weave in ("dense", "event"):
        _, v_off, o_off = run_cell(stage, preset, frontend, weave, False)
        _, v_on, o_on = run_cell(stage, preset, frontend, weave, True)
        # (a) semantic equality, full per-window trajectory included
        for name, a, b in zip(o_off._fields, o_off, o_on):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"[{weave}] WindowOut.{name} moved with telemetry")
        for key in SEMANTIC_VIEWS:
            np.testing.assert_array_equal(
                np.asarray(v_off[key]), np.asarray(v_on[key]),
                err_msg=f"[{weave}] view {key!r} moved with telemetry")
        assert not any(k.startswith("tele_") for k in v_off)
        assert all(k in v_on for k in TELE_KEYS)
        planes[weave] = (v_on, o_on)

    # (b) engine-invariant planes
    (vd, _), (ve, _) = planes["dense"], planes["event"]
    for k in TELE_KEYS:
        np.testing.assert_array_equal(
            np.asarray(vd[k]), np.asarray(ve[k]),
            err_msg=f"plane {k!r} differs between weave engines")

    # (c) per-window histogram totals == served reads (both histograms)
    v, o = planes["dense"]
    served = np.asarray(o.served_rd)        # (W,) summed over channels
    for hk in ("tele_hist_rd_ticks", "tele_hist_if_ps"):
        h = np.asarray(v[hk])
        tot = h.sum(axis=tuple(range(1, h.ndim)))
        np.testing.assert_array_equal(tot, served, err_msg=hk)
    np.testing.assert_array_equal(
        np.asarray(v["tele_n_cas_rd"]).sum(axis=-1), served)
    np.testing.assert_array_equal(
        np.asarray(v["tele_n_cas_wr"]).sum(axis=-1),
        np.asarray(o.served_wr))


def test_log2_bucket_integer_exact():
    v = jnp.asarray([1, 2, 3, 4, 7, 8, 1023, 1024, 1 << 22, (1 << 24) + 5])
    b = np.asarray(dram.log2_bucket(v))
    assert b.tolist() == [0, 1, 1, 2, 2, 3, 9, 10, 22, dram.N_HIST - 1]
    # exact powers of two land in their own bucket, never the previous
    p = np.asarray(dram.log2_bucket(jnp.asarray([2 ** k for k in range(23)])))
    assert p.tolist() == list(range(23))


def test_hist_percentiles_and_edges():
    edges = hist_edges()
    assert edges[0] == 1 and edges[-1] == 2.0 ** dram.N_HIST
    # all mass in bucket 4 ([16, 32)): every quantile inside that bucket
    h = np.zeros(dram.N_HIST, np.int64)
    h[4] = 100
    p50, p95, p99 = hist_percentiles(h)
    assert 16.0 <= p50 <= p95 <= p99 <= 32.0
    # empty histogram: nan, not a crash
    assert np.isnan(hist_percentiles(np.zeros(dram.N_HIST))).all()
    # leading axes reduce by summation
    hh = np.stack([h, h])
    np.testing.assert_allclose(hist_percentiles(hh),
                               hist_percentiles(2 * h))


def test_spearman_ties_and_degenerate():
    assert spearman([1, 2, 3, 4], [10, 20, 30, 40]) == pytest.approx(1.0)
    assert spearman([1, 2, 3, 4], [40, 30, 20, 10]) == pytest.approx(-1.0)
    # zero variance (the decoupled app view): 0.0, not nan
    assert spearman([5, 5, 5, 5], [1, 2, 3, 4]) == 0.0
    # ties get average ranks (monotone with ties is still rho=1 on the
    # untied pairs' ordering)
    r = spearman([1, 1, 2, 3], [10, 10, 20, 30])
    assert r == pytest.approx(1.0)
    with pytest.raises(ValueError):
        spearman([1, 2], [1, 2, 3])


@pytest.fixture(scope="module")
def tele_run():
    """One telemetry-on mix replay shared by the reduction tests."""
    cfg, views, outs = run_cell("10-delay-buffer", "ddr4_2666", mix(),
                                "dense", True)
    return cfg, views, outs


def test_collect_and_summarize(tele_run):
    cfg, views, outs = tele_run
    rec = collect(cfg, views, outs)
    s = summarize(rec)
    c = s["commands"]            # summarize reduces post-warmup only
    assert c["cas_rd"] == int(
        np.asarray(outs.served_rd)[cfg.warmup:].sum())
    rl = s["row_locality"]
    assert rl["hits"] >= 0 and rl["misses"] >= 0 and rl["conflicts"] >= 0
    assert 0.0 <= rl["hit_rate"] <= 1.0
    assert 0.0 <= s["bank_busy_frac"] <= 1.0
    for view in ("sim_lat_ns", "if_lat_ns"):
        p = s[view]
        assert p["p50"] <= p["p95"] <= p["p99"]
    # off-config collect must refuse
    cfg_off = dataclasses.replace(cfg, telemetry=False)
    with pytest.raises(ValueError):
        collect(cfg_off, views, outs)


def test_json_and_perfetto_export(tele_run, tmp_path):
    cfg, views, outs = tele_run
    rec = collect(cfg, views, outs)

    jpath = tmp_path / "tele.json"
    report = to_json(rec, jpath)
    loaded = json.loads(jpath.read_text())
    assert loaded["schema"] == report["schema"] == "repro.obs/telemetry-v1"
    assert set(loaded["series"]) == set(TELE_KEYS)

    tpath = tmp_path / "trace.json"
    trace = to_perfetto(rec, tpath)
    n = validate_perfetto(trace)
    assert n == len(trace["traceEvents"]) > 0
    # the file round-trips through plain JSON and stays valid
    assert validate_perfetto(json.loads(tpath.read_text())) == n
    # one command counter track per channel per window
    cmd = [e for e in trace["traceEvents"]
           if e["ph"] == "C" and "commands" in e["name"]]
    assert len(cmd) == cfg.windows * cfg.platform.dram.n_channels

    for bad in (
        {},                                           # no traceEvents
        dict(traceEvents=[]),                         # empty
        dict(traceEvents=[dict(ph="Z", pid=1, name="x")]),   # bad phase
        dict(traceEvents=[dict(ph="C", pid=1, name="x commands",
                               ts=0.0, args={})]),    # empty counter args
        dict(traceEvents=[dict(ph="C", pid=1, name="queue",
                               ts=0.0, args=dict(d=1))]),  # no cmd track
    ):
        with pytest.raises(ValueError):
            validate_perfetto(bad)


def test_window_series_and_divergence(tele_run):
    cfg, views, outs = tele_run
    rec = collect(cfg, views, outs)
    ser = window_series(rec)
    span = cfg.windows - cfg.warmup
    for k in ("sim_lat_ns", "if_lat_ns", "app_lat_ns", "app_rate"):
        assert ser[k].shape == (span,), k
    d = divergence(rec)
    for k in ("rho_sim_if", "rho_sim_app", "rho_if_app",
              "rho_sim_app_level", "rho_sim_rate"):
        assert -1.0 <= d[k] <= 1.0, k

    # the decoupling signature: a broken stage's app view never moves,
    # so its response correlation is exactly 0
    cfg0, v0, o0 = run_cell("01-baseline", "ddr4_2666", mix(),
                            "dense", True)
    rec0 = collect(cfg0, v0, o0)
    assert divergence(rec0)["rho_sim_app"] == 0.0

    report = divergence_report({"01-baseline": rec0,
                                "10-delay-buffer": rec})
    assert [r["stage"] for r in report["ladder"]] == [
        "01-baseline", "10-delay-buffer"]
    assert report["schema"] == "repro.obs/perspectives-v1"
    assert isinstance(report["monotone_ok"], bool)
    json.dumps(report)                   # artifact is JSON-serializable


def test_row_locality_identity(tele_run):
    """Each request retires with exactly one CAS, so commands bound the
    locality split: cas >= hits, act >= pre over any long-enough span
    (refresh-forced re-ACTs make strict per-window identities clamp —
    documented in `TickTele`)."""
    cfg, views, outs = tele_run
    n_cas = int(np.asarray(views["tele_n_cas_rd"]).sum()
                + np.asarray(views["tele_n_cas_wr"]).sum())
    n_act = int(np.asarray(views["tele_n_act"]).sum())
    n_pre = int(np.asarray(views["tele_n_pre"]).sum())
    assert n_cas >= n_act - n_pre >= 0 or n_act >= n_pre
    assert n_act > 0 and n_cas > 0