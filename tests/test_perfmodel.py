"""Perf-model unit tests: HLO parsing + roofline math."""
import jax
import jax.numpy as jnp
import pytest

from repro.perfmodel import hlo_cost, roofline

SAMPLE_HLO = """
HloModule test

%cond (arg: (s32[], f32[8,8])) -> pred[] {
  %arg = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%body (arg.1: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %arg.1 = (s32[], f32[8,8]) parameter(0)
  %x = f32[8,8] get-tuple-element(%arg.1), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={}
  %i2 = s32[] get-tuple-element(%arg.1), index=0
  ROOT %t = (s32[], f32[8,8]) tuple(%i2, %ar)
}

ENTRY %main (p0: f32[8,8]) -> f32[8,8] {
  %p0 = f32[8,8]{1,0} parameter(0)
  %c = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%c, %p0)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body
  %g = f32[8,8]{1,0} get-tuple-element(%w), index=1
  %ag = f32[16,8]{1,0} all-gather(%g), dimensions={0}
  %sl = f32[8,8]{1,0} slice(%ag), slice={[0:8], [0:8]}
  ROOT %out = f32[8,8]{1,0} dot(%sl, %g), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_while_trip_scaling():
    res = hlo_cost.analyze(SAMPLE_HLO)
    # dot in body: 2*8*8*8 = 1024 flops, x5 trips; entry dot: 1024
    assert res["flops"] == pytest.approx(1024 * 5 + 1024)
    # all-reduce 256 B x5; all-gather 512 B x1
    assert res["bytes_by_op"]["all-reduce"] == 256 * 5
    assert res["bytes_by_op"]["all-gather"] == 512
    assert res["total_bytes"] == 256 * 5 + 512


def test_roofline_terms_and_bottleneck():
    r = roofline.make(
        "a", "s", "pod", 256,
        cost={"flops": 197e12, "bytes accessed": 819e9 * 2},
        collectives={"total_bytes": 50e9 * 0.5},
        model_flops=197e12 * 256 * 0.4,
        bytes_per_device=1e9)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(2.0)
    assert r.collective_s == pytest.approx(0.5)
    assert r.bottleneck == "memory"
    assert r.useful_ratio == pytest.approx(0.4)


def test_model_flops():
    assert roofline.model_flops("train", 10, 100) == 6000
    assert roofline.model_flops("prefill", 10, 100) == 2000


def test_active_params_moe():
    struct = dict(
        we_gate=jax.ShapeDtypeStruct((8, 4, 4), jnp.float32),
        dense=jax.ShapeDtypeStruct((4, 4), jnp.float32))
    n = roofline.count_active_params(struct, top_k=2, n_experts=8)
    assert n == 8 * 16 * 2 // 8 + 16


def test_real_compiled_module_parses():
    """End-to-end: compile a tiny scanned function and check the
    parser scales the loop body."""
    def f(x, w):
        def body(c, _):
            return c @ w, None
        c, _ = jax.lax.scan(body, x, None, length=7)
        return c

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((32, 32), jnp.float32),
        jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
    res = hlo_cost.analyze(comp.as_text())
    expect = 2 * 32 * 32 * 32 * 7
    assert res["flops"] == pytest.approx(expect, rel=0.01)
