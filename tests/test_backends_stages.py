"""Coverage for `backends.make_policy` and `stages.get_stage`."""
import dataclasses

import pytest

from repro.core import STAGES, get_stage, make_policy
from repro.core.backends import BACKENDS, MC_PHY_TICKS


def test_make_policy_known_backends():
    for name in ("ramulator", "ramulator2", "dramsim3"):
        pol = make_policy(name)
        assert pol is BACKENDS[name]
        assert pol.name == name
        assert pol.mc_extra_ticks == 0


def test_make_policy_unknown_backend_raises():
    with pytest.raises(ValueError, match="unknown backend"):
        make_policy("gem5")
    # the error names the available flavors
    with pytest.raises(ValueError, match="ramulator2"):
        make_policy("nope")


def test_make_policy_delay_buffer_adds_phy_ticks():
    for name in BACKENDS:
        pol = make_policy(name, delay_buffer=True)
        assert pol.mc_extra_ticks == MC_PHY_TICKS
        # everything else is untouched
        assert dataclasses.replace(pol, mc_extra_ticks=0) == BACKENDS[name]


def test_get_stage_returns_registered_config():
    cfg = get_stage("04-model-correct")
    assert cfg is STAGES["04-model-correct"]
    assert cfg.pi_latency


def test_get_stage_override_does_not_mutate_registry():
    cfg = get_stage("01-baseline", windows=7, warmup=2)
    assert (cfg.windows, cfg.warmup) == (7, 2)
    assert STAGES["01-baseline"].windows != 7
    assert cfg.name == "01-baseline"


def test_get_stage_unknown_raises_with_catalog():
    with pytest.raises(ValueError, match="unknown stage"):
        get_stage("99-nope")
    with pytest.raises(ValueError, match="01-baseline"):
        get_stage("99-nope")


def test_get_stage_bad_override_field_raises():
    with pytest.raises(TypeError):
        get_stage("01-baseline", not_a_field=1)
