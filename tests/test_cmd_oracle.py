"""The command-stream recorder + protocol oracle (`repro.oracle`).

The contract of `StageConfig.cmd_trace` mirrors `telemetry`:

* **off (default)** — the traced computation is the exact historical
  graph: every semantic output is bit-identical with the flag on vs
  off, on both weave engines, and no ``cmd_*`` view exists;
* **on** — both engines record the *same* per-channel command stream
  (grant-for-grant, refresh-for-refresh), and that stream passes the
  full `repro.oracle.RULES` legality check.

Plus unit coverage of the extraction layer, one synthetic-violation
case per checker rule (the checker must *fire*, not just pass on
legal streams), and the ``.cmd.trace`` export/validate round trip.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import get_stage
from repro.core.dram import ACT, PRE, RD, REF, WR
from repro.core.platform import run_frontend
from repro.core.presets import platform_for
from repro.core.workload import MessFrontend
from repro.obs.export import to_cmd_trace, validate_cmd_trace
from repro.oracle import (RULES, CommandStream, check_stream, diff_streams,
                          extract_stream, stream_stats)
from repro.oracle.stream import CMD_KEYS
from repro.traces import assign_traces, split_cores
from repro.traces.frontend import TraceFrontend
from repro.traces.kernels import gups, stream

FAST = dict(windows=6, warmup=2)

SEMANTIC_VIEWS = ("sim_bw_gbs", "sim_lat_ns", "if_bw_gbs", "if_lat_ns",
                  "app_bw_gbs", "app_lat_ns", "chase_lat_ns",
                  "n_rd", "n_wr", "l_ir_final", "injected")

D4 = platform_for("ddr4_2666").dram
D5 = platform_for("ddr5_4800").dram


def mess(pace=8, wr=16):
    def build(cfg):
        fe = MessFrontend(jnp.int32(pace), jnp.int32(wr),
                          cfg.workload_config())
        return lambda: run_frontend(cfg, fe)

    return build


def mix(n=192):
    apps = [stream(n=n), gups(n=n)]

    def build(cfg):
        m = assign_traces(apps,
                          split_cores(2, cfg.workload_config().n_cores),
                          phase_offsets=None)
        return lambda: run_frontend(
            cfg, TraceFrontend(m, cfg.workload_config()))

    build.full_budget = True
    return build


def run_cell(stage, preset, frontend, weave, cmd_trace):
    cfg = get_stage(stage, preset=preset, weave=weave,
                    cmd_trace=cmd_trace, **FAST)
    if weave == "event" and getattr(frontend, "full_budget", False):
        cfg = dataclasses.replace(
            cfg, weave_events=cfg.clock().ticks_per_window_static)
    views, outs = jax.device_get(jax.jit(frontend(cfg))())
    return cfg, views, outs


# the DDR5 cell fires hundreds of per-bank refreshes inside FAST
# windows (tREFI=292 ticks); DDR4's all-bank path is covered by the
# fuzzer and benchmarks/cmd_oracle.py at longer horizons
GRID = [
    ("10-delay-buffer", "ddr4_2666", mess()),
    ("04-model-correct", "ddr5_4800", mix()),
]
_IDS = [f"{g[0]}-{g[1]}-{g[2].__qualname__.split('.')[0]}" for g in GRID]


@pytest.mark.parametrize("stage,preset,frontend", GRID, ids=_IDS)
def test_cmd_trace_off_and_on_agree(stage, preset, frontend):
    """One grid cell, both engines: (a) the flag changes no semantic
    output bit; (b) dense and event record the identical stream; (c)
    the stream is protocol-legal, refresh deadlines included."""
    streams = {}
    for weave in ("dense", "event"):
        cfg, v_off, o_off = run_cell(stage, preset, frontend, weave, False)
        _, v_on, o_on = run_cell(stage, preset, frontend, weave, True)
        for name, a, b in zip(o_off._fields, o_off, o_on):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"[{weave}] WindowOut.{name} moved with cmd_trace")
        for key in SEMANTIC_VIEWS:
            np.testing.assert_array_equal(
                np.asarray(v_off[key]), np.asarray(v_on[key]),
                err_msg=f"[{weave}] view {key!r} moved with cmd_trace")
        assert not any(k.startswith("cmd_") for k in v_off)
        assert all(k in v_on for k in CMD_KEYS)
        streams[weave] = extract_stream(v_on, cfg.platform.dram)

    # (b) grant-for-grant engine agreement
    assert diff_streams(streams["dense"], streams["event"]) is None
    s = streams["dense"]
    assert len(s) > 0
    if preset == "ddr5_4800":
        assert s.counts()["REF"] > 0          # REFsb path exercised

    # (c) full legality, exact refresh deadlines
    end_tick = int(cfg.clock().window_end_tick(cfg.windows - 1))
    rep = check_stream(s, end_tick=end_tick)
    assert rep.ok, rep.summary()
    assert all(rep.n_checked[r] > 0 for r in
               ("state-cas-open", "trcd", "tccd-s", "trrd-s"))

    # stats reduce consistently: per-channel mixes sum to the totals
    st = stream_stats(s, span_ticks=end_tick)
    for name, tot in s.counts().items():
        assert int(st[name].sum()) == tot
    assert (st["bw_gbs"] >= 0).all()


# ---------------------------------------------------------------- unit layer


def mk(d, rows):
    """Hand-built single-channel stream: rows (t, cmd, rank, bank, row)."""
    a = np.asarray(rows, np.int64).reshape(-1, 5)
    return CommandStream(
        dram=d, t=a[:, 0], cmd=a[:, 1].astype(np.int32),
        channel=np.zeros(len(a), np.int32),
        rank=a[:, 2].astype(np.int32), bank=a[:, 3].astype(np.int32),
        row=a[:, 4].astype(np.int32))


def test_checker_accepts_legal_sequence():
    s = mk(D4, [
        (100, ACT, 0, 0, 5),
        (119, RD, 0, 0, 5),                      # +tRCD
        (143, PRE, 0, 0, -1),                    # +tRAS
        (162, ACT, 0, 0, 7),                     # +tRP (and tRC exactly)
        (181, WR, 0, 0, 7),
        (219, PRE, 0, 0, -1),                    # +tCWL+tBL+tWR
    ])
    rep = check_stream(s)
    assert rep.ok, rep.summary()
    assert rep.n_commands == 6 and rep.counts["ACT"] == 2


#: rule -> (device, rows) where the checker must fire exactly that rule
#: (a few cases unavoidably co-fire a coupled rule; asserted per-rule)
VIOLATIONS = {
    "state-act-closed": (D4, [(100, ACT, 0, 0, 5), (110, ACT, 0, 0, 6)]),
    "state-cas-open": (D4, [(100, RD, 0, 0, 5)]),
    "state-pre-open": (D4, [(100, PRE, 0, 0, -1)]),
    "trcd": (D4, [(100, ACT, 0, 0, 5), (110, RD, 0, 0, 5)]),
    "tras": (D4, [(100, ACT, 0, 0, 5), (130, PRE, 0, 0, -1)]),
    "trp": (D4, [(100, ACT, 0, 0, 5), (119, RD, 0, 0, 5),
                 (143, PRE, 0, 0, -1), (155, ACT, 0, 0, 6)]),
    "trc": (D4, [(100, ACT, 0, 0, 5), (119, RD, 0, 0, 5),
                 (143, PRE, 0, 0, -1), (161, ACT, 0, 0, 6)]),
    "trtp": (D4, [(100, ACT, 0, 0, 5), (119, RD, 0, 0, 5),
                  (128, PRE, 0, 0, -1)]),
    "twr": (D4, [(100, ACT, 0, 0, 5), (119, WR, 0, 0, 5),
                 (150, PRE, 0, 0, -1)]),
    "tccd-s": (D4, [(100, ACT, 0, 0, 5), (101, ACT, 1, 0, 5),
                    (120, RD, 0, 0, 5), (122, RD, 1, 0, 5)]),
    "tccd-l": (D4, [(100, ACT, 0, 0, 5), (107, ACT, 0, 1, 5),
                    (126, RD, 0, 0, 5), (131, RD, 0, 1, 5)]),
    # the rank-switching burst at 125 occupies the bus for
    # tBL + tRTRS = 6; the follow-up at gap 5 passes tCCD_S but not bus
    "bus": (D4, [(100, ACT, 0, 0, 5), (102, ACT, 1, 0, 5),
                 (119, RD, 0, 0, 5), (125, RD, 1, 0, 5),
                 (130, RD, 0, 0, 5)]),
    "twtr": (D4, [(100, ACT, 0, 0, 5), (105, ACT, 0, 4, 5),
                  (119, WR, 0, 0, 5), (130, RD, 0, 4, 5)]),
    "trtw": (D4, [(100, ACT, 0, 0, 5), (105, ACT, 0, 4, 5),
                  (124, RD, 0, 0, 5), (130, WR, 0, 4, 5)]),
    "trrd-s": (D4, [(100, ACT, 0, 0, 5), (102, ACT, 0, 8, 5)]),
    "trrd-l": (D4, [(100, ACT, 0, 0, 5), (105, ACT, 0, 1, 5)]),
    "tfaw": (D4, [(100, ACT, 0, 0, 5), (107, ACT, 0, 4, 5),
                  (114, ACT, 0, 8, 5), (121, ACT, 0, 12, 5),
                  (126, ACT, 0, 2, 5)]),
    "trfc": (D4, [(10400, REF, 0, -1, -1), (10500, ACT, 0, 0, 5)]),
    "trefi": (D4, [(10401, REF, 0, -1, -1)]),
    "ref-rotation": (D5, [(292, REF, 0, 1, -1)]),
}


@pytest.mark.parametrize("rule", sorted(VIOLATIONS))
def test_checker_fires_rule(rule):
    d, rows = VIOLATIONS[rule]
    rep = check_stream(mk(d, rows))
    assert rep.violation_counts[rule] > 0, rep.summary()
    assert not rep.ok
    ex = [v for v in rep.violations if v["rule"] == rule]
    assert ex and isinstance(ex[0]["detail"], str)


def test_checker_ref_missed_and_exact_deadlines():
    # an empty stream misses every due refresh on every channel
    rep = check_stream(mk(D4, np.zeros((0, 5), np.int64)),
                       end_tick=int(D4.tREFI) + 100)
    assert rep.violation_counts["ref-missed"] == D4.n_channels
    # exact-deadline firing (staggered rank 1) is legal; the other,
    # empty channels of the synthetic stream still read as missed
    dl0, dl1 = D4.tREFI, D4.tREFI + D4.tREFI // 2
    s = mk(D4, [(dl0, REF, 0, -1, -1), (dl1, REF, 1, -1, -1)])
    rep = check_stream(s, end_tick=dl1 + 1)
    assert rep.violation_counts["trefi"] == 0
    assert not any(v["channel"] == 0 for v in rep.violations)
    # ref_slack loosens the deadline rule (experiments knob)
    late = mk(D4, [(dl0 + 3, REF, 0, -1, -1)])
    assert check_stream(late).violation_counts["trefi"] == 1
    assert check_stream(late, ref_slack=3).violation_counts["trefi"] == 0


def test_checker_refsb_rotation_legal():
    dl0, dl1 = D5.tREFI, D5.tREFI + D5.tREFI // 2
    s = mk(D5, [(dl0, REF, 0, 0, -1), (dl1, REF, 1, 0, -1),
                (dl0 + D5.tREFI, REF, 0, 1, -1)])
    rep = check_stream(s)
    assert rep.ok, rep.summary()
    assert rep.n_checked["ref-rotation"] == 3


def test_rules_table_is_complete():
    rep = check_stream(mk(D4, [(100, ACT, 0, 0, 5)]))
    assert set(rep.n_checked) == set(RULES)
    assert set(rep.violation_counts) == set(RULES)
    assert all(isinstance(v, str) and v for v in RULES.values())


def test_extract_stream_refuses_bad_views():
    with pytest.raises(ValueError, match="cmd_trace=True"):
        extract_stream({}, D4)
    _, views, _ = run_cell("01-baseline", "ddr4_2666", mess(),
                           "dense", True)
    s = extract_stream(views, D4)
    assert len(s) > 0
    # a vmapped/duplicated batch repeats grant times: refused
    doubled = {k: np.concatenate([np.asarray(views[k])] * 2)
               for k in CMD_KEYS}
    with pytest.raises(ValueError, match="strictly increasing"):
        extract_stream(doubled, D4)


def test_diff_streams_localizes_divergence():
    rows = [(100, ACT, 0, 0, 5), (119, RD, 0, 0, 5)]
    a, b = mk(D4, rows), mk(D4, rows)
    assert diff_streams(a, b) is None
    b.row[1] = 6
    d = diff_streams(a, b)
    assert d["index"] == 1 and d["a"]["row"] == 5 and d["b"]["row"] == 6
    c = mk(D4, rows + [(143, PRE, 0, 0, -1)])
    d = diff_streams(a, c)
    assert d["n_a"] == 2 and d["n_b"] == 3 and d["index"] == 2


# ------------------------------------------------------------- export layer


def test_cmd_trace_export_round_trip(tmp_path):
    s = mk(D4, [
        (100, ACT, 0, 0, 5), (119, RD, 0, 0, 5), (143, PRE, 0, 0, -1),
        (10400, REF, 0, -1, -1),
    ])
    path = tmp_path / "t.cmd.trace"
    text = to_cmd_trace(s, path=path, preset="ddr4_2666")
    assert validate_cmd_trace(text) == len(s)
    assert validate_cmd_trace(path.read_text()) == len(s)
    rows = text.strip().splitlines()[3:]
    assert rows[0] == "100,0,ACT,0,0,0,5"
    assert rows[-1] == "10400,0,REFab,0,-1,-1,-1"

    # DDR5 REFsb carries its bank (and group), row -1
    s5 = mk(D5, [(292, REF, 0, 3, -1)])
    t5 = to_cmd_trace(s5, preset="ddr5_4800")
    assert validate_cmd_trace(t5) == 1
    assert t5.strip().splitlines()[-1] == (
        f"292,0,REFsb,0,{3 // D5.banks_per_group},3,-1")


def test_validate_cmd_trace_rejects_corruption():
    s = mk(D4, [(100, ACT, 0, 0, 5), (119, RD, 0, 0, 5),
                (10400, REF, 0, -1, -1)])
    text = to_cmd_trace(s, preset="ddr4_2666")
    lines = text.strip().splitlines()
    bad = [
        "\n".join(lines[1:]) + "\n",                      # no marker
        "\n".join(lines[:3]) + "\n",                      # no rows
        text.replace("ACT", "XYZ"),                       # bad mnemonic
        text.replace("100,0,ACT,0,0,0,5",
                     "100,0,ACT,0,0,0,-1"),               # ACT without row
        text.replace("10400,0,REFab,0,-1,-1,-1",
                     "10400,0,REFab,0,0,0,-1"),           # REFab with bank
        text.replace("119,0,RD,0,0,0,5",
                     "119,0,RD,0,1,0,5"),                 # group mismatch
        text.replace("119,0,RD", "99,0,RD"),              # time regression
        text.replace("119,0,RD,0,0,0,5", "119,0,RD,0,0,0"),   # field count
    ]
    for i, b in enumerate(bad):
        with pytest.raises(ValueError):
            validate_cmd_trace(b)
            pytest.fail(f"corruption variant {i} accepted")


def test_mess_sweep_refuses_cmd_trace():
    from repro.core import sweep

    cfg = get_stage("01-baseline", cmd_trace=True, **FAST)
    with pytest.raises(ValueError, match="cmd_trace"):
        sweep(cfg, paces=(4,), write_mixes=(0,))
