"""End-to-end system behaviour: Mess sweeps, stage progression, views.

These are the integration tests of the paper's central claims, run at
reduced window counts (CI-speed) over the full platform stack.
"""
import numpy as np
import pytest

from repro.core import STAGES, get_stage, sweep
from repro.core import reference

FAST = dict(windows=32, warmup=12)
PACES = (2, 16, 48)


@pytest.fixture(scope="module")
def results():
    out = {}
    for name in ("01-baseline", "04-model-correct", "07-prefetch"):
        out[name] = sweep(get_stage(name, **FAST), paces=PACES,
                          write_mixes=(0, 16))
    return out


def test_all_stages_defined():
    assert len(STAGES) == 11
    assert list(STAGES)[0] == "00-damov-native"


def test_bandwidth_monotone_then_saturates(results):
    for name, res in results.items():
        bw = res.sim_bw[0]
        assert bw[0] < bw[-1] * 1.05, name
        assert np.all(np.diff(bw) > -0.15 * bw[:-1]), (name, bw)


def test_views_decoupled_only_in_baseline(results):
    base = results["01-baseline"]
    corr = results["04-model-correct"]
    # baseline: app latency flat (max-min < 2 ns across load)
    assert np.ptp(base.app_lat[0]) < 2.0
    # corrected: app latency grows with load
    assert corr.app_lat[0][-1] > corr.app_lat[0][0] * 1.5


def test_corrected_stage_approaches_reference():
    """Validation the paper's way: compare the app view against the
    measured Skylake curves.  We require qualitative agreement:
    unloaded within a factor band and saturation bandwidth within 25%."""
    res = sweep(get_stage("07-prefetch", **FAST), paces=(1, 32, 64),
                write_mixes=(0,))
    unloaded = res.app_lat[0, 0]
    assert 0.7 * reference.UNLOADED_NS < unloaded < 1.6 * reference.UNLOADED_NS
    sat_bw = res.app_bw[0].max()
    ref_bw = reference.max_bandwidth_gbs(1.0)
    assert sat_bw > 0.6 * ref_bw
    assert sat_bw < 1.1 * ref_bw


def test_interface_view_never_exceeds_theory_after_fix():
    res = sweep(get_stage("03-ps-clock", **FAST), paces=(64,),
                write_mixes=(0,))
    peak = get_stage("03-ps-clock").platform.dram.peak_gbs
    assert res.if_bw.max() <= peak * 1.02


def test_baseline_interface_exceeds_theory():
    """Fig. 2c: the broken interface reports > theoretical-max bw."""
    res = sweep(get_stage("01-baseline", **FAST), paces=(64,),
                write_mixes=(0,))
    peak = get_stage("01-baseline").platform.dram.peak_gbs
    assert res.if_bw.max() > peak


def test_sweep_rows_roundtrip():
    res = sweep(get_stage("01-baseline", windows=16, warmup=4),
                paces=(2, 8), write_mixes=(0,))
    rows = res.to_rows()
    assert len(rows) == 2
    assert {r["stage"] for r in rows} == {"01-baseline"}
