"""Three-view platform behaviour: the paper's findings as assertions."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import get_stage, run_point

FAST = dict(windows=24, warmup=8)


def point(stage, pace=32, wr=0, **kw):
    cfg = get_stage(stage, **{**FAST, **kw})
    out = jax.jit(lambda p, w: run_point(cfg, p, w))(
        jnp.int32(pace), jnp.int32(wr))
    return {k: float(v) for k, v in out.items()}


def test_baseline_app_view_is_flat_24ns():
    """Fig. 2d: app-view load-to-use latency flat at ~24 ns (50 CPU
    cycles) regardless of load — the decoupling bug."""
    lo = point("01-baseline", pace=2)
    hi = point("01-baseline", pace=48)
    assert lo["app_lat_ns"] == pytest.approx(24.3, abs=1.0)
    assert hi["app_lat_ns"] == pytest.approx(lo["app_lat_ns"], abs=0.5)


def test_baseline_interface_bw_inflated_1575x():
    """Fig. 2c: broken clock scaling -> CPU sees memory 1.575x too fast."""
    out = point("01-baseline", pace=16)
    assert out["if_bw_gbs"] / out["sim_bw_gbs"] == pytest.approx(
        1.575, rel=1e-2)


def test_clock_scale_stage_underruns_by_frequency_rounding():
    """Fig. 3: integer freqRatio=2 -> interface bw = 0.7875x simulator."""
    out = point("02-clock-scale", pace=16)
    assert out["if_bw_gbs"] / out["sim_bw_gbs"] == pytest.approx(
        0.7875, rel=1e-2)


def test_ps_clock_aligns_views():
    """Fig. 4: picosecond clocking -> interface and simulator agree."""
    out = point("03-ps-clock", pace=16)
    assert out["if_bw_gbs"] / out["sim_bw_gbs"] == pytest.approx(
        1.0, rel=1e-3)


def test_pi_controller_recouples_app_view():
    """Fig. 5: with the PI-controlled immediate-response latency the
    app view tracks the interface latency instead of sitting at 24 ns.

    The PI estimator's 0.95 retention needs ~60 windows to converge,
    so this test runs longer than the FAST default."""
    out = point("04-model-correct", pace=32, windows=96, warmup=48)
    assert out["app_lat_ns"] > 60.0
    assert out["app_lat_ns"] == pytest.approx(out["if_lat_ns"], rel=0.35)
    base = point("01-baseline", pace=32)
    assert base["app_lat_ns"] == pytest.approx(24.3, abs=1.0)


def test_unloaded_latency_hierarchy():
    """Unloaded: sim view ~ 43-55 ns (paper: 43); corrected app view
    above it (cache path + NOC), in the neighborhood of the actual
    89 ns."""
    out = point("04-model-correct", pace=1, windows=96, warmup=48)
    assert 35.0 < out["sim_lat_ns"] < 65.0
    assert 70.0 < out["app_lat_ns"] < 110.0


def test_xor_mapping_restores_rw_gradient():
    """Fig. 6a: with the XOR mapping, write-heavy mixes saturate lower;
    the simple mapping hides the gradient.

    Deep saturation at max pace is the regime where the event weave's
    static budget binds (XOR traffic issues a command on ~60% of
    ticks), so this direct `run_point` probe pins the dense reference
    oracle — sweep users get the same exactness automatically via
    `mess.sweep`'s knee routing + saturation fallback."""
    xor_r = point("05-addrmap", pace=64, wr=0, weave="dense")
    xor_w = point("05-addrmap", pace=64, wr=32, weave="dense")
    assert xor_w["sim_bw_gbs"] < 0.85 * xor_r["sim_bw_gbs"]


def test_noc_adds_latency():
    """Fig. 6b: the mesh NOC adds ~10 ns across the range."""
    base = point("04-model-correct", pace=8)
    noc = point("06-noc", pace=8, mapping="skylake_xor")
    delta = noc["app_lat_ns"] - base["app_lat_ns"]
    assert 4.0 < delta < 30.0


def test_delay_buffer_raises_unloaded_latency():
    """Stage 10 (paper future work): MC/PHY delay-buffer lifts the
    simulated unloaded latency toward the actual system."""
    base = point("07-prefetch", pace=1)
    buf = point("10-delay-buffer", pace=1)
    assert buf["app_lat_ns"] > base["app_lat_ns"] + 10.0


def test_backend_flavors_all_run():
    for st in ("07-prefetch", "08-dramsim3", "09-ramulator2"):
        out = point(st, pace=24)
        assert out["sim_bw_gbs"] > 10.0
        assert out["n_rd"] > 0
