"""Minimal property-based testing harness.

`hypothesis` is not installable in this offline container (documented
in DESIGN.md §testing); this module provides the subset we need:
deterministic multi-seed random case generation with failure-case
reporting.  Strategies are plain callables (rng -> value).
"""
from __future__ import annotations

import functools

import numpy as np


def forall(n_cases: int = 50, seed: int = 0, **strategies):
    """Decorator: run the test for `n_cases` random draws.

    Each strategy is called with a numpy Generator; the drawn values
    are passed as keyword args.  On failure the case index and drawn
    values are attached to the assertion.
    """
    def deco(fn):
        def wrapper():
            # NOTE: signature intentionally empty — pytest must not
            # mistake the strategy kwargs for fixtures.
            for case in range(n_cases):
                rng = np.random.default_rng(seed * 100003 + case)
                drawn = {k: s(rng) for k, s in strategies.items()}
                try:
                    fn(**drawn)
                except AssertionError as e:
                    raise AssertionError(
                        f"property failed on case {case}: "
                        f"{ {k: _short(v) for k, v in drawn.items()} }"
                    ) from e
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco


def _short(v):
    a = np.asarray(v)
    if a.size > 8:
        return f"array{a.shape}:{a.dtype}"
    return v


# -- strategies --------------------------------------------------------------

def integers(lo: int, hi: int):
    return lambda rng: int(rng.integers(lo, hi + 1))


def uint32_arrays(max_len: int = 4096):
    def strat(rng):
        n = int(rng.integers(1, max_len + 1))
        return rng.integers(0, 2 ** 32, size=n, dtype=np.uint32)
    return strat


def int32_grid(shape, lo=0, hi=100):
    return lambda rng: rng.integers(lo, hi, size=shape, dtype=np.int32)


def floats(lo=-1e3, hi=1e3):
    return lambda rng: float(rng.uniform(lo, hi))


def float_arrays(shape, scale=1.0):
    return lambda rng: (rng.standard_normal(shape) * scale).astype(
        np.float32)
