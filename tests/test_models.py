"""Model-family invariants: decode == forward, finiteness, shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.common import ModelConfig
from repro.models.registry import get_model

FAMS = {
    "dense": ModelConfig(name="t-dense", family="dense", n_layers=3,
                         d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                         vocab=97, qkv_bias=True, dtype=jnp.float32),
    "moe": ModelConfig(name="t-moe", family="moe", n_layers=2, d_model=32,
                       n_heads=4, n_kv_heads=2, d_ff=64, vocab=53,
                       n_experts=4, top_k=2, dense_residual=True,
                       capacity_factor=2.0, dtype=jnp.float32),
    "xlstm": ModelConfig(name="t-xlstm", family="ssm", n_layers=4,
                         d_model=32, n_heads=2, n_kv_heads=2, d_ff=0,
                         vocab=61, slstm_every=2, dtype=jnp.float32),
    "mamba": ModelConfig(name="t-mamba", family="ssm", n_layers=2,
                         d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                         vocab=61, ssm_state=8, ssm_head_dim=16,
                         ssm_chunk=4, slstm_every=0, dtype=jnp.float32),
    "hybrid": ModelConfig(name="t-zamba", family="hybrid", n_layers=4,
                          d_model=32, n_heads=4, n_kv_heads=4, d_ff=64,
                          vocab=61, ssm_state=8, ssm_head_dim=16,
                          ssm_chunk=4, attn_every=2, dtype=jnp.float32),
    "vlm": ModelConfig(name="t-vlm", family="vlm", n_layers=4, d_model=32,
                       n_heads=4, n_kv_heads=2, d_ff=64, vocab=61,
                       cross_attn_every=2, n_ctx_tokens=6,
                       dtype=jnp.float32),
    "audio": ModelConfig(name="t-whisper", family="audio", n_layers=3,
                         d_model=32, n_heads=4, n_kv_heads=4, d_ff=64,
                         vocab=61, n_encoder_layers=2, n_ctx_tokens=6,
                         dtype=jnp.float32),
}


def make_batch(api, b=2, s=9):
    cfg = api.cfg
    rng = np.random.default_rng(0)
    batch = dict(tokens=jnp.asarray(
        rng.integers(0, cfg.vocab, (b, s)), jnp.int32))
    if api.needs_ctx:
        batch["ctx"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_ctx_tokens, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("fam", sorted(FAMS))
def test_forward_shapes_and_finite(fam):
    cfg = FAMS[fam]
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(api)
    logits = api.forward(params, batch)
    assert logits.shape == (2, 9, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("fam", sorted(FAMS))
def test_decode_matches_forward(fam):
    """Step-by-step decode equals the parallel forward pass — the
    core serving-correctness invariant for every family."""
    cfg = FAMS[fam]
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(api)
    logits = api.forward(params, batch)
    cache = api.init_cache(2, 16)
    if api.needs_ctx:
        cache = api.fill_ctx(params, cache, batch["ctx"])
    for t in range(batch["tokens"].shape[1]):
        dlg, cache = api.decode(params, cache, batch["tokens"][:, t])
    # forward uses bf16 probabilities (§Perf iter 1); decode keeps
    # fp32 -> agreement at bf16 resolution
    np.testing.assert_allclose(np.asarray(dlg),
                               np.asarray(logits[:, -1]),
                               atol=6e-3, rtol=6e-3)


@pytest.mark.parametrize("fam", sorted(FAMS))
def test_gradients_flow_and_finite(fam):
    cfg = FAMS[fam]
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(api)
    batch["labels"] = batch["tokens"]

    from repro.train.step import build_loss_fn
    loss, grads = jax.value_and_grad(build_loss_fn(api))(params, batch)
    assert bool(jnp.isfinite(loss))
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves)
    # most leaves get nonzero gradient.  vlm is exempt from the high
    # bar: its Flamingo-style tanh gates init to 0, which blocks
    # gradient flow into the cross-attn weights at init BY DESIGN
    # (the gate itself still receives gradient).
    nz = sum(float(jnp.abs(g).sum()) > 0 for g in leaves)
    frac = 0.5 if fam == "vlm" else 0.9
    assert nz >= frac * len(leaves), f"{nz}/{len(leaves)}"


def test_moe_matches_bruteforce_top2():
    from repro.models import moe as M
    from repro.models import common as cm
    cfg = FAMS["moe"]
    p = M.init_moe(cfg, jax.random.PRNGKey(0), 0.02)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, aux = M.moe_mlp(cfg, p, x)
    gates = jax.nn.softmax(x @ p["router"])
    v, i = jax.lax.top_k(gates, 2)
    v = v / v.sum(-1, keepdims=True)
    out = jnp.zeros_like(x)
    for e in range(cfg.n_experts):
        h = jax.nn.silu(x @ p["we_gate"][e]) * (x @ p["we_up"][e])
        ye = h @ p["we_down"][e]
        w = (i[..., 0] == e) * v[..., 0] + (i[..., 1] == e) * v[..., 1]
        out = out + w[..., None] * ye
    out = out + cm.mlp(cfg, p["dense"], x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(out),
                               atol=1e-5, rtol=1e-5)
    assert float(aux) > 0.9      # balanced-ish router at init ~ 1.0


def test_mlstm_parallel_equals_recurrent():
    from repro.models import xlstm as X
    cfg = FAMS["xlstm"]
    p = X.init_mlstm(cfg, jax.random.PRNGKey(3), 0.02)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 11, cfg.d_model),
                          jnp.float32)
    y_par = X.mlstm_fwd(cfg, p, x)
    st = X.init_mlstm_state(cfg, 2)
    ys = []
    for t in range(11):
        st, yt = X.mlstm_step(cfg, p, st, x[:, t])
        ys.append(yt)
    y_rec = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_rec),
                               atol=2e-5, rtol=2e-5)


def test_ssd_chunked_scan_invariant_to_chunk_size():
    """SSD must give the same result for any chunk length."""
    import dataclasses
    from repro.models import mamba2 as M
    cfg = FAMS["mamba"]
    p = M.init_mamba(cfg, jax.random.PRNGKey(5), 0.02)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 16, cfg.d_model),
                          jnp.float32)
    outs = []
    for q in (2, 4, 8, 16):
        c = dataclasses.replace(cfg, ssm_chunk=q)
        outs.append(np.asarray(M.mamba_fwd(c, p, x)))
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, atol=2e-5, rtol=2e-5)
