"""Golden equivalence: the event-horizon weave engine vs the dense scan.

The event engine (`StageConfig.weave="event"`) must be **bit-identical**
to the dense reference scan — same `WindowOut` trajectory, same three
views — because idle ticks contribute nothing and `dram.next_event` is
exact (never early, never late).  The golden grid below spans every
device preset, all three clock models, representative stages
(baseline / integer-ratio / ps+PI / full-stack / row-hit-cap backend),
both frontends (Mess pace + trace replay, solo and multiprogrammed
mix), and one and two sockets — all under the *default* clock-derived
event budget.

Set ``REPRO_FULL_GOLDEN=1`` to run the full cross product
(presets x stages x frontends x sockets) instead of the curated
covering subset — several dozen compiles, for release validation runs.

The event budget is a static scan length: when offered traffic exceeds
what it covers, the engine must degrade *gracefully* — events spill
into the next window and the window is flagged in the ``weave_sat``
view, never silently wrong.  The saturation test forces that regime.
"""
import dataclasses
import itertools
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dram, get_stage
from repro.core.clocking import CLOCK_MODES, event_budget, make_clock
from repro.core.platform import run_frontend
from repro.core.presets import PRESETS, platform_for
from repro.core.workload import MessFrontend
from repro.traces import assign_traces, split_cores
from repro.traces.frontend import TraceFrontend
from repro.traces.kernels import gups, stream

FAST = dict(windows=6, warmup=2)

#: semantic view keys that must match bit-identically across engines
#: (weave_events is engine-specific by design; weave_sat must be zero)
SEMANTIC_VIEWS = ("sim_bw_gbs", "sim_lat_ns", "if_bw_gbs", "if_lat_ns",
                  "app_bw_gbs", "app_lat_ns", "chase_lat_ns",
                  "n_rd", "n_wr", "l_ir_final", "injected")


def mess(*points):
    """A Mess frontend over a small vmapped (pace, wr) batch: one
    compile covers several operating points."""
    pace = jnp.asarray([p for p, _ in points], jnp.int32)
    wr = jnp.asarray([w for _, w in points], jnp.int32)

    def build(cfg):
        fn = jax.vmap(lambda p, w: run_frontend(
            cfg, MessFrontend(p, w, cfg.workload_config())))
        return lambda: fn(pace, wr)

    return build


def solo(n=256):
    trace = stream(n=n)

    def build(cfg):
        return lambda: run_frontend(
            cfg, TraceFrontend(trace, cfg.workload_config()))

    # MSHR-throttled replay slams the platform at full demand (the
    # saturated regime by construction), so the trace cells verify the
    # *engine* under a covering budget; the user-facing replay path
    # (`repro.traces.replay`) adds the dense fallback for saturated
    # rows on top — tested separately below.
    build.full_budget = True
    return build


def mix(n=192):
    apps = [stream(n=n), gups(n=n)]

    def build(cfg):
        m = assign_traces(apps, split_cores(2, cfg.workload_config().n_cores),
                          phase_offsets=None)
        return lambda: run_frontend(
            cfg, TraceFrontend(m, cfg.workload_config()))

    build.full_budget = True
    return build


def run_pair(stage, preset, frontend, n_sockets=1, **kw):
    out = {}
    for weave in ("dense", "event"):
        cfg = get_stage(stage, preset=preset, n_sockets=n_sockets,
                        weave=weave, **FAST, **kw)
        if weave == "event" and getattr(frontend, "full_budget", False):
            cfg = dataclasses.replace(
                cfg, weave_events=cfg.clock().ticks_per_window_static)
        out[weave] = jax.device_get(jax.jit(frontend(cfg))())
    return out["dense"], out["event"]


def assert_bit_identical(dense, event):
    (vd, od), (ve, oe) = dense, event
    # the full per-window trajectory, every field, every window
    for name, a, b in zip(od._fields, od, oe):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"WindowOut.{name} differs between weave engines")
    for key in SEMANTIC_VIEWS:
        np.testing.assert_array_equal(
            np.asarray(vd[key]), np.asarray(ve[key]),
            err_msg=f"view {key!r} differs between weave engines")
    assert int(np.sum(ve["weave_sat"])) == 0, \
        "event budget saturated on a golden-grid point"


# Curated covering subset: every preset, clock mode, frontend kind,
# socket count, and the policy flavors that change scheduling.
GRID = [
    ("01-baseline", "ddr4_2666", mess((4, 0), (8, 16)), 1),
    ("02-clock-scale", "ddr5_4800", mess((8, 16),), 1),
    ("04-model-correct", "hbm2e", mess((8, 0), (16, 16)), 2),
    ("09-ramulator2", "ddr4_2666", mess((8, 16),), 1),
    ("10-delay-buffer", "ddr4_2666", mess((4, 32), (8, 0)), 1),
    ("04-model-correct", "ddr4_2666", solo(), 1),
    ("10-delay-buffer", "ddr5_4800", mix(), 1),
    ("01-baseline", "hbm2e", mix(), 2),
]

if os.environ.get("REPRO_FULL_GOLDEN"):
    GRID = [
        (stage, preset, fe, ns)
        for stage, preset, ns in itertools.product(
            ("01-baseline", "02-clock-scale", "04-model-correct",
             "08-dramsim3", "09-ramulator2", "10-delay-buffer"),
            PRESETS, (1, 2))
        for fe in (mess((4, 0), (8, 16), (16, 32)), solo(), mix())
    ]

_IDS = [f"{g[0]}-{g[1]}-{g[2].__qualname__.split('.')[0]}-{g[3]}s"
        for g in GRID]


@pytest.mark.parametrize("stage,preset,frontend,n_sockets", GRID, ids=_IDS)
def test_event_engine_bit_identical(stage, preset, frontend, n_sockets):
    dense, event = run_pair(stage, preset, frontend, n_sockets)
    assert_bit_identical(dense, event)


#: joint static-flag cells: telemetry x cmd_trace x 2 sockets — the
#: three flags must compose without perturbing the historical graph
JOINT_GRID = [
    ("04-model-correct", "ddr5_4800", mix(), 2),
    ("10-delay-buffer", "ddr4_2666", mix(), 2),
]
_JIDS = [f"{g[0]}-{g[1]}-{g[3]}s" for g in JOINT_GRID]


@pytest.mark.parametrize("stage,preset,frontend,n_sockets", JOINT_GRID,
                         ids=_JIDS)
def test_joint_static_flags_bit_identical(stage, preset, frontend,
                                          n_sockets):
    """All three static flags on at once (telemetry + cmd_trace, two
    sockets), both engines: (a) no semantic output moves vs the
    flags-off graph; (b) telemetry planes and the recorded command
    stream are engine-invariant; (c) the stream is protocol-legal."""
    from repro.obs import TELE_KEYS
    from repro.oracle import check_stream, diff_streams, extract_stream
    from repro.oracle.stream import CMD_KEYS

    on = {}
    for weave in ("dense", "event"):
        runs = {}
        for flags in (False, True):
            cfg = get_stage(stage, preset=preset, n_sockets=n_sockets,
                            weave=weave, telemetry=flags,
                            cmd_trace=flags, **FAST)
            if weave == "event" and getattr(frontend, "full_budget",
                                            False):
                cfg = dataclasses.replace(
                    cfg, weave_events=cfg.clock().ticks_per_window_static)
            runs[flags] = (cfg, *jax.device_get(jax.jit(frontend(cfg))()))
        (_, v_off, o_off), (cfg, v_on, o_on) = runs[False], runs[True]
        for name, a, b in zip(o_off._fields, o_off, o_on):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"[{weave}] WindowOut.{name} moved with "
                        "telemetry+cmd_trace")
        for key in SEMANTIC_VIEWS:
            np.testing.assert_array_equal(
                np.asarray(v_off[key]), np.asarray(v_on[key]),
                err_msg=f"[{weave}] view {key!r} moved with "
                        "telemetry+cmd_trace")
        assert all(k in v_on for k in TELE_KEYS + tuple(CMD_KEYS))
        on[weave] = (cfg, v_on)

    (cfg, vd), (_, ve) = on["dense"], on["event"]
    for k in TELE_KEYS:
        np.testing.assert_array_equal(
            np.asarray(vd[k]), np.asarray(ve[k]),
            err_msg=f"plane {k!r} differs between weave engines")
    sd = extract_stream(vd, cfg.platform.dram)
    se = extract_stream(ve, cfg.platform.dram)
    assert diff_streams(sd, se) is None
    assert len(sd) > 0
    rep = check_stream(
        sd, end_tick=int(cfg.clock().window_end_tick(cfg.windows - 1)))
    assert rep.ok, rep.summary()


def test_replay_fallback_makes_saturated_replay_exact():
    """The user-facing replay path: solo replay is MSHR-hot and
    exhausts the default event budget, so `_replay_exact` re-runs the
    flagged rows through the dense oracle — results must equal an
    all-dense replay bit for bit (the weave_sat column keeps the
    first-pass diagnostic)."""
    from repro.traces import replay_suite, stack_traces

    batch = stack_traces([stream(n=192), gups(n=160)])
    out = {}
    for weave in ("dense", "event"):
        cfg = get_stage("04-model-correct", weave=weave, **FAST)
        out[weave] = replay_suite(cfg, batch)
    assert (out["event"]["weave_sat"] > 0).any()     # fallback exercised
    for k in out["dense"]:
        if k == "weave_sat":
            continue
        np.testing.assert_array_equal(
            np.asarray(out["dense"][k]), np.asarray(out["event"][k]),
            err_msg=f"replay key {k!r} differs after dense fallback")


def test_sweep_routing_is_exact_across_the_knee():
    """`mess.sweep` routes pace points between the engines and re-runs
    any saturation-flagged event point dense: the full curve — through
    the knee into deep saturation — must match an all-dense sweep."""
    from repro.core import sweep

    paces = (2, 8, 48)
    res = {}
    for weave in ("dense", "event"):
        cfg = get_stage("05-addrmap", weave=weave, **FAST)
        res[weave] = sweep(cfg, paces=paces, write_mixes=(0, 32))
    for field in ("sim_bw", "sim_lat", "if_bw", "if_lat",
                  "app_bw", "app_lat", "chase_lat"):
        np.testing.assert_array_equal(
            getattr(res["dense"], field), getattr(res["event"], field),
            err_msg=f"sweep field {field!r} differs between engines")


def test_budget_saturation_reported_never_silent():
    """A deliberately tiny budget at max pace: the engine must keep
    producing sane output (events spill into later windows) and flag
    every saturated window in the weave_sat view."""
    frontend = mess((64, 0),)
    cfg = get_stage("04-model-correct", weave="event", weave_events=16,
                    **FAST)
    views, _ = jax.device_get(jax.jit(frontend(cfg))())
    assert int(np.sum(views["weave_sat"])) > 0          # reported
    assert int(np.sum(views["n_rd"])) > 0               # still serving
    for key in SEMANTIC_VIEWS:
        assert np.all(np.isfinite(np.asarray(views[key], np.float64))), key


def test_event_budget_gives_3x_step_reduction():
    """Acceptance: the derived static event budget cuts weave scan
    steps per window by >= 3x on every preset x clock mode."""
    for preset, mode in itertools.product(PRESETS, CLOCK_MODES):
        clock = make_clock(mode, platform_for(preset))
        ratio = clock.ticks_per_window_static / clock.events_per_window_static
        assert ratio >= 3.0, (preset, mode, ratio)
        assert clock.events_per_window_static == event_budget(
            clock.ticks_per_window_static, platform_for(preset).dram)


def test_next_event_exact_candidates():
    """Unit-level: arrivals, command readiness, and refresh deadlines
    produce exact per-channel event times."""
    d = platform_for("ddr4_2666").dram
    pol = get_stage("01-baseline").policy
    q = dram.init_queue(d, pol)
    b = dram.init_banks(d)
    nev = lambda q, b, t: np.asarray(dram.next_event(
        q, b, jnp.int32(t), jnp.int32(1 << 20), dram=d, policy=pol))

    # empty queue: only the refresh deadline can be an event
    ev = nev(q, b, 0)
    assert (ev == np.asarray(b.next_ref).min(axis=1)).all()

    # a future arrival is that channel's event
    q1 = q._replace(valid=q.valid.at[2, 0].set(1),
                    arrival=q.arrival.at[2, 0].set(50),
                    row=q.row.at[2, 0].set(5))
    assert nev(q1, b, 0)[2] == 50

    # an arrived row-miss on a closed bank: ACT issuable immediately,
    # so the event horizon is the very next tick
    q2 = q._replace(valid=q.valid.at[0, 0].set(1),
                    row=q.row.at[0, 0].set(5))
    assert nev(q2, b, 0)[0] == 1

    # after the ACT at t=1, the CAS is the event, tRCD later
    q3, b3, _ = dram.tick(q2, b, jnp.int32(1), dram=d, policy=pol,
                          tick2cpu_num=750, tick2cpu_den=1,
                          cpu_ps_per_clk=476)
    assert nev(q3, b3, 1)[0] == 1 + d.tRCD


def test_bank_planes_cached_and_exact():
    for preset in PRESETS:
        d = platform_for(preset).dram
        planes = dram.bank_planes(d)
        assert planes is dram.bank_planes(d)            # lru-cached
        rb = np.arange(d.banks_per_channel)
        assert (planes.rank_of == rb // d.banks_per_rank).all()
        assert (planes.grp_of
                == (rb % d.banks_per_rank) // d.banks_per_group).all()
        assert (planes.bank_in_rank == rb % d.banks_per_rank).all()
        assert (planes.cidx == np.arange(d.n_channels)).all()
