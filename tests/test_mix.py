"""Multiprogrammed per-core replay: mixes, phase offsets, two sockets."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import get_stage
from repro.core.workload import WorkloadConfig
from repro.traces import (assign_traces, make_trace, mix_stats, replay_mix,
                          split_cores, stack_mixes)
from repro.traces.kernels import gups, pointer_chase, stream

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
FAST = dict(windows=16, warmup=4)


# ----------------------------------------------------------- construction

def test_assign_traces_builds_per_core_batch():
    a = make_trace(np.ones(100), np.zeros(100), np.zeros(100), 1 << 12)
    b = make_trace(np.full(50, 2), np.ones(50), np.zeros(50), 1 << 10)
    mix = assign_traces([a, b], [0, 0, 1, -1])
    assert mix.n_cores == 4
    assert list(np.asarray(mix.length)) == [100, 100, 50, 0]
    assert list(np.asarray(mix.footprint_lines)) == [1 << 12, 1 << 12,
                                                     1 << 10, 1]
    assert list(np.asarray(mix.app_id)) == [0, 0, 1, -1]
    assert int(mix.region_lines) == 1 << 12    # max footprint
    # per-core streams: padded to a common static shape
    assert mix.delta.shape == (4, mix.n_slots)
    assert (np.asarray(mix.delta)[2, :50] == 2).all()
    assert (np.asarray(mix.is_write)[2, :50] == 1).all()
    st = mix_stats(mix)
    assert st["cores_per_app"] == {0: 2, 1: 1}
    assert st["idle_cores"] == 1


def test_assign_traces_validates():
    t = make_trace([1], [0], [0], 64)
    with pytest.raises(ValueError):
        assign_traces([t], [0, 0])                 # chase core not idle
    with pytest.raises(ValueError):
        assign_traces([t], [1, -1])                # app index out of range
    with pytest.raises(ValueError):
        assign_traces([t], [-1, -1])               # app 0 unassigned
    with pytest.raises(ValueError):
        assign_traces([t], [0, -1], phase_offsets=[0])   # wrong length


def test_phase_offsets_rotate_stream_with_wrap():
    """Default (wrap=True): an offset core replays the rotated stream
    [off, n) ++ [0, off) from cursor 0 — the steady-state pipeline."""
    deltas = np.arange(1, 65)
    t = make_trace(deltas, np.zeros(64), np.zeros(64), 1 << 10)
    mix = assign_traces([t], [0, 0, -1], phase_offsets=[0, 10, 0])
    assert list(np.asarray(mix.pos0)) == [0, 0, 0]     # cursor at 0
    assert list(np.asarray(mix.length)) == [64, 64, 0]  # full stream
    np.testing.assert_array_equal(
        np.asarray(mix.delta)[1, :64],
        np.concatenate([deltas[10:], deltas[:10]]))
    # the running delta sum starts where the unrotated stream's
    # position-10 prefix sum left off (int32 semantics)
    assert int(mix.line_cum0[1]) == int(
        np.asarray(deltas[:10], np.int32).sum(dtype=np.int32))
    # offsets wrap modulo the stream length
    wrapped = assign_traces([t], [0, -1], phase_offsets=[64 + 10, 0])
    np.testing.assert_array_equal(np.asarray(wrapped.delta)[0, :64],
                                  np.asarray(mix.delta)[1, :64])


def test_phase_offsets_truncate_without_wrap():
    """wrap=False keeps the one-shot model: cursor starts at the
    offset, the suffix [off, n) is all that replays."""
    deltas = np.arange(1, 65)
    t = make_trace(deltas, np.zeros(64), np.zeros(64), 1 << 10)
    mix = assign_traces([t], [0, 0, -1], phase_offsets=[0, 10, 0],
                        wrap=False)
    assert list(np.asarray(mix.pos0)) == [0, 10, 0]
    np.testing.assert_array_equal(np.asarray(mix.delta)[1, :64], deltas)
    assert int(mix.line_cum0[1]) == int(
        np.asarray(deltas[:10], np.int32).sum(dtype=np.int32))
    # offsets beyond the stream clip to its length
    clipped = assign_traces([t], [0, -1], phase_offsets=[500, 0],
                            wrap=False)
    assert int(clipped.pos0[0]) == 64


def test_split_cores_even_blocks():
    asn = split_cores(3, 24)
    assert len(asn) == 24 and asn[-1] == -1
    counts = [asn.count(a) for a in range(3)]
    assert sum(counts) == 23 and max(counts) - min(counts) <= 1
    # blocks are contiguous (producer/consumer neighbourhoods)
    assert asn[:-1] == sorted(asn[:-1])
    with pytest.raises(ValueError):
        split_cores(24, 24)


# ------------------------------------------------------------- semantics

def test_offset_core_replays_full_stream_with_wrap():
    """Wraparound replay (ROADMAP follow-up): the offset core plays
    [off, n) ++ [0, off), so the total lines replayed per core — and
    hence its completion window — is unchanged by the offset."""
    t = make_trace(np.ones(512), np.zeros(512), np.zeros(512), 1 << 12)
    cfg = get_stage("03-ps-clock", **FAST)
    plain = assign_traces([t], [0] * 23 + [-1])
    mix = assign_traces([t], [0] * 23 + [-1],
                        phase_offsets=[0] * 22 + [256, 0])
    assert (np.asarray(mix.length) == np.asarray(plain.length)).all()
    out = replay_mix(cfg, mix)
    rt = out["core_runtime_windows"]
    assert out["core_done"].all()
    # every core consumed its full 512 accesses — the offset core is
    # not truncated, so it completes alongside its lockstep peers
    # (pricing is address-independent; the rotation only moves which
    # lines it touches, not how many)
    assert (rt[:23] == rt[0]).all()


def test_offset_core_finishes_earlier_without_wrap():
    """The one-shot model (wrap=False): a core starting mid-stream
    consumes fewer accesses, so its completion window comes first."""
    t = make_trace(np.ones(512), np.zeros(512), np.zeros(512), 1 << 12)
    cfg = get_stage("03-ps-clock", **FAST)
    mix = assign_traces([t], [0] * 23 + [-1],
                        phase_offsets=[0] * 22 + [256, 0], wrap=False)
    out = replay_mix(cfg, mix)
    rt = out["core_runtime_windows"]
    assert out["core_done"].all()
    assert rt[22] < rt[0]                      # half the stream left
    assert (rt[:22] == rt[0]).all()            # lockstep otherwise


def test_mix_apps_match_solo_runtimes_below_knee():
    """Acceptance: two distinct traces on disjoint core sets under
    hbm2e reproduce their solo runtimes within 2% when total demand
    stays below the device knee."""
    A, B = stream(n=1536), gups(n=1536)
    cfg = get_stage("04-model-correct", preset="hbm2e",
                    windows=40, warmup=8)
    aA = [0] * 8 + [-1] * 16                   # A alone on cores 0-7
    aB = [-1] * 12 + [0] * 8 + [-1] * 4        # B alone on cores 12-19
    aAB = [0] * 8 + [-1] * 4 + [1] * 8 + [-1] * 4
    soloA = replay_mix(cfg, assign_traces([A], aA))
    soloB = replay_mix(cfg, assign_traces([B], aB))
    both = replay_mix(cfg, assign_traces([A, B], aAB))
    assert both["app_done"].all()
    for i, solo in enumerate((soloA, soloB)):
        assert solo["app_done"][0]
        rel = abs(both["app_runtime_windows"][i]
                  / solo["app_runtime_windows"][0] - 1)
        assert rel <= 0.02, (i, both["app_runtime_windows"],
                             solo["app_runtime_windows"])


def test_mix_contention_slows_latency_bound_app():
    """The multiprogrammed regime the shared-cursor frontend could not
    express: a streaming neighbour inflates the latency-bound app's
    in-mix runtime well beyond its isolated runtime."""
    S, C = stream(n=2048), pointer_chase(n=128)
    cfg = get_stage("04-model-correct", windows=48, warmup=8)
    alone = replay_mix(cfg, assign_traces(
        [C], [-1] * 11 + [0] * 12 + [-1]))
    mixed = replay_mix(cfg, assign_traces(
        [S, C], [0] * 11 + [1] * 12 + [-1]))
    assert alone["app_done"][0] and mixed["app_done"][1]
    assert (mixed["app_runtime_windows"][1]
            > 1.5 * alone["app_runtime_windows"][0])


def test_stack_mixes_batches_and_validates():
    t1 = make_trace(np.ones(64), np.zeros(64), np.zeros(64), 256)
    t2 = make_trace(np.ones(200), np.zeros(200), np.zeros(200), 256)
    m1 = assign_traces([t1], [0, 0, -1])
    m2 = assign_traces([t2], [0, -1, -1])
    batch = stack_mixes([m1, m2])
    assert batch.delta.shape[0] == 2
    assert batch.delta.shape[-1] == m2.n_slots
    with pytest.raises(ValueError):
        stack_mixes([m1, assign_traces([t1], [0, -1])])


# ------------------------------------------------------------ two sockets

def test_socket_geometry_properties():
    one = WorkloadConfig()
    two = WorkloadConfig(n_sockets=2)
    assert (one.n_cores, one.n_traffic, one.chase_core) == (24, 23, 23)
    assert (two.n_cores, two.n_traffic, two.chase_core) == (48, 47, 47)


def test_second_socket_lifts_hbm2e_frontend_ceiling():
    """Acceptance: 47 traffic cores push HBM2e past the ~200 GB/s
    single-socket frontend ceiling (>300 GB/s demand served)."""
    import jax.numpy as jnp
    from repro.core import run_point

    bw = {}
    for ns in (1, 2):
        # max-pace saturation probe: pin the dense reference oracle
        # (the event engine's static budget binds past the knee and
        # would flag, not reproduce, this regime)
        cfg = get_stage("04-model-correct", preset="hbm2e", n_sockets=ns,
                        weave="dense", **FAST)
        v = run_point(cfg, jnp.int32(64), jnp.int32(0))
        bw[ns] = float(v["sim_bw_gbs"])
    assert bw[1] < 210                         # the documented ceiling
    assert bw[2] > 300


def test_partitioned_channel_ownership_splits_sockets():
    """Partitioned mode confines each socket to its channel half; the
    platform still serves traffic from both sockets."""
    import jax.numpy as jnp
    from repro.core import run_point

    cfg = get_stage("03-ps-clock", preset="hbm2e", n_sockets=2,
                    socket_channels="partitioned", **FAST)
    v = run_point(cfg, jnp.int32(32), jnp.int32(0))
    assert float(v["sim_bw_gbs"]) > 150
    assert cfg.workload_config().socket_channels == "partitioned"


def test_two_socket_mix_replay():
    """A 48-core mix replays with per-app runtimes on both sockets."""
    A, B = stream(n=512), gups(n=512)
    cfg = get_stage("03-ps-clock", preset="hbm2e", n_sockets=2,
                    windows=32, warmup=4)
    asn = [0] * 24 + [1] * 23 + [-1]           # one app per socket
    out = replay_mix(cfg, assign_traces([A, B], asn))
    assert out["app_runtime_windows"].shape == (2,)
    assert out["app_done"].all()
    assert out["sim_bw_gbs"] > 0


# ------------------------------------------------- sharded bit-identity

_SHARD_SCRIPT = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    assert jax.device_count() == 4, jax.devices()
    from repro.core import get_stage
    from repro.core.platform import run_frontend
    from repro.core.shard import sharded_vmap
    from repro.traces import assign_traces, split_cores, stack_mixes
    from repro.traces.frontend import TraceFrontend
    from repro.traces.kernels import gups, pointer_chase, stream
    from repro.traces.replay import VIEW_KEYS

    cfg = get_stage("03-ps-clock", windows=6, warmup=2)
    def one(mix):
        views, outs = run_frontend(cfg, TraceFrontend(
            mix, cfg.workload_config()))
        return dict({k: views[k] for k in VIEW_KEYS},
                    progress=outs.progress)

    apps = [stream(n=256), gups(n=256), pointer_chase(n=128)]
    mixes = stack_mixes([
        assign_traces(apps[:2], split_cores(2, 24)),
        assign_traces(apps[1:], split_cores(2, 24)),
        assign_traces([apps[0], apps[2]], split_cores(2, 24),
                      phase_offsets=[0] * 12 + [64] * 11 + [0]),
    ])
    sharded = jax.device_get(sharded_vmap(one, n_devices=4)(mixes))
    single = jax.device_get(sharded_vmap(one, n_devices=1)(mixes))
    for k in single:
        a, b = np.asarray(sharded[k]), np.asarray(single[k])
        assert a.shape == b.shape, k
        assert (a == b).all(), (k, a, b)     # BIT-identical, not approx
    print("OK")
""")


def test_sharded_mix_axis_bit_identical():
    """Acceptance: the per-core (mix) batch axis shards across devices
    bit-identically to the single-device vmap path."""
    env = dict(os.environ,
               XLA_FLAGS=(os.environ.get("XLA_FLAGS", "")
                          + " --xla_force_host_platform_device_count=4"),
               JAX_PLATFORMS="cpu",
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT],
                          capture_output=True, text=True, env=env,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "OK" in proc.stdout
