"""Docs stay true: doctests run, cross-references resolve."""
import doctest
import importlib.util
import os
import sys

ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))


def _load_check_docs():
    spec = importlib.util.spec_from_file_location(
        "check_docs", os.path.join(ROOT, "scripts", "check_docs.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_docs_doctests_pass():
    for name in ("ARCHITECTURE.md", "VALIDATION.md", "WORKLOADS.md",
                 "SERVING.md"):
        path = os.path.join(ROOT, "docs", name)
        res = doctest.testfile(path, module_relative=False, verbose=False)
        assert res.failed == 0, f"{name}: {res.failed} doctest failures"


def test_docs_cross_references_resolve(capsys):
    mod = _load_check_docs()
    assert mod.main() == 0, capsys.readouterr().out


def test_checker_catches_broken_references():
    mod = _load_check_docs()
    mod._errors.clear()
    mod.check_modules("fake.md", "see repro.core.not_a_module_xyz")
    mod.check_paths("fake.md", "see src/repro/core/nope_missing.py")
    mod.check_links("fake.md", "[x](does/not/exist.md)")
    assert len(mod._errors) == 3
    mod._errors.clear()


def test_readme_links_docs():
    with open(os.path.join(ROOT, "README.md")) as f:
        readme = f.read()
    assert "docs/ARCHITECTURE.md" in readme
    assert "docs/VALIDATION.md" in readme


def test_benchmark_registry_is_alphabetized():
    """`run.py --list` / `reanalyze --list-benchmarks` print the
    registry in iteration order — keep it alphabetized and complete."""
    sys.path.insert(0, ROOT)
    try:
        from benchmarks.registry import BENCHMARKS
    finally:
        sys.path.remove(ROOT)
    names = list(BENCHMARKS)
    assert names == sorted(names), names
    assert "cmd_oracle" in names
    for spec in BENCHMARKS.values():
        assert spec.name and spec.description
        assert spec.module.startswith("benchmarks.")
