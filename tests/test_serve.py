"""Serving engine: continuous batching semantics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ModelConfig
from repro.models.registry import get_model
from repro.serve.engine import Engine, Request

CFG = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab=128, dtype=jnp.float32)


def setup():
    api = get_model(CFG)
    params = api.init(jax.random.PRNGKey(0))
    return api, params


def test_engine_completes_all_requests():
    api, params = setup()
    eng = Engine(api, params, n_slots=3, max_seq=64)
    for i in range(7):
        eng.submit(Request(rid=i, prompt=[1 + i, 2, 3], max_new=5))
    done = eng.run()
    assert len(done) == 7
    assert all(len(r.out) == 5 for r in done)


def test_engine_matches_single_stream_decode():
    """A request decoded through the batched engine produces the same
    tokens as a dedicated single-sequence greedy decode."""
    api, params = setup()
    prompt = [5, 9, 2, 17]
    # engine path (with other traffic in neighboring slots)
    eng = Engine(api, params, n_slots=2, max_seq=64)
    eng.submit(Request(rid=0, prompt=prompt, max_new=6))
    eng.submit(Request(rid=1, prompt=[3, 3, 3], max_new=6))
    done = eng.run()
    out_engine = next(r.out for r in done if r.rid == 0)
    # reference path
    cache = api.init_cache(1, 64)
    toks = list(prompt)
    out_ref = []
    for t in toks:
        logits, cache = api.decode(params, cache,
                                   jnp.asarray([t], jnp.int32))
    for _ in range(6):
        nxt = int(jnp.argmax(logits[0]))
        out_ref.append(nxt)
        logits, cache = api.decode(params, cache,
                                   jnp.asarray([nxt], jnp.int32))
    assert out_engine == out_ref


def test_slot_reuse_resets_state():
    """A slot reused by a second request must not leak the first
    request's KV cache."""
    api, params = setup()
    eng = Engine(api, params, n_slots=1, max_seq=64)
    eng.submit(Request(rid=0, prompt=[7, 8, 9], max_new=4))
    eng.submit(Request(rid=1, prompt=[7, 8, 9], max_new=4))
    done = eng.run()
    assert len(done) == 2
    assert done[0].out == done[1].out     # identical prompt -> identical out


# ------------------------------------------------ SlotPool + hardening

def test_slotpool_fifo_and_recycling():
    from repro.serve.engine import SlotPool
    pool = SlotPool(2)
    for i in range(5):
        pool.submit(i)
    placed = pool.admit()
    assert placed == [(0, 0), (1, 1)]          # FIFO into slot order
    assert pool.admit() == []                  # no free slot -> no-op
    assert pool.pending() and len(pool.queue) == 3
    pool.free(1)
    assert pool.admit() == [(1, 2)]            # recycled slot, next in line
    assert [r for _, r in pool.active()] == [0, 2]
    for s, _ in pool.active():
        pool.free(s)
    assert pool.admit() == [(0, 3), (1, 4)]
    pool.free(0)
    pool.free(1)
    assert not pool.pending()


def test_slotpool_validates_n_slots():
    import pytest
    from repro.serve.engine import SlotPool
    with pytest.raises(ValueError):
        SlotPool(0)


def test_submit_beyond_n_slots_queues():
    """More submissions than slots: the surplus waits in the queue and
    drains as slots recycle — nothing is dropped or double-placed."""
    api, params = setup()
    eng = Engine(api, params, n_slots=2, max_seq=64)
    for i in range(6):
        eng.submit(Request(rid=i, prompt=[1 + i], max_new=3))
    eng.tick()
    assert sum(r is not None for r in eng.slots) == 2
    assert len(eng.queue) == 4                 # surplus queued, not lost
    done = eng.run()
    assert sorted(r.rid for r in done) == list(range(6))
    assert all(len(r.out) == 3 for r in done)


def test_zero_length_request_rejected():
    import pytest
    api, params = setup()
    eng = Engine(api, params, n_slots=1, max_seq=64)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(rid=0, prompt=[], max_new=4))
    with pytest.raises(ValueError, match="max_new"):
        eng.submit(Request(rid=1, prompt=[3], max_new=0))
    assert not eng.pool.pending()              # nothing half-submitted


def test_run_max_ticks_resumes():
    """`run` hitting max_ticks mid-schedule is a pause, not a loss:
    queued requests stay queued, partial outputs are kept, and a
    second `run` finishes the schedule exactly."""
    api, params = setup()
    eng = Engine(api, params, n_slots=1, max_seq=64)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=[5 + i, 2], max_new=4))
    done = eng.run(max_ticks=3)
    assert done == []                          # nobody finished in 3 ticks
    assert len(eng.queue) == 2                 # rids 1,2 still queued
    partial = eng.slots[0]
    assert partial.rid == 0 and 0 < len(partial.out) < 4
    done += eng.run()
    assert sorted(r.rid for r in done) == [0, 1, 2]
    assert all(len(r.out) == 4 for r in done)


def test_same_tick_admit_and_complete_collected():
    """A one-token prompt with max_new=1 completes on its admission
    tick; `run` must still return it (regression: the old `run`
    snapshotted in-flight requests before ticking and lost these)."""
    api, params = setup()
    eng = Engine(api, params, n_slots=2, max_seq=64)
    eng.submit(Request(rid=0, prompt=[9], max_new=1))
    done = eng.run()
    assert [r.rid for r in done] == [0]
    assert len(done[0].out) == 1 and done[0].done
    assert not eng.pool.pending()
