"""Serving engine: continuous batching semantics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ModelConfig
from repro.models.registry import get_model
from repro.serve.engine import Engine, Request

CFG = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab=128, dtype=jnp.float32)


def setup():
    api = get_model(CFG)
    params = api.init(jax.random.PRNGKey(0))
    return api, params


def test_engine_completes_all_requests():
    api, params = setup()
    eng = Engine(api, params, n_slots=3, max_seq=64)
    for i in range(7):
        eng.submit(Request(rid=i, prompt=[1 + i, 2, 3], max_new=5))
    done = eng.run()
    assert len(done) == 7
    assert all(len(r.out) == 5 for r in done)


def test_engine_matches_single_stream_decode():
    """A request decoded through the batched engine produces the same
    tokens as a dedicated single-sequence greedy decode."""
    api, params = setup()
    prompt = [5, 9, 2, 17]
    # engine path (with other traffic in neighboring slots)
    eng = Engine(api, params, n_slots=2, max_seq=64)
    eng.submit(Request(rid=0, prompt=prompt, max_new=6))
    eng.submit(Request(rid=1, prompt=[3, 3, 3], max_new=6))
    done = eng.run()
    out_engine = next(r.out for r in done if r.rid == 0)
    # reference path
    cache = api.init_cache(1, 64)
    toks = list(prompt)
    out_ref = []
    for t in toks:
        logits, cache = api.decode(params, cache,
                                   jnp.asarray([t], jnp.int32))
    for _ in range(6):
        nxt = int(jnp.argmax(logits[0]))
        out_ref.append(nxt)
        logits, cache = api.decode(params, cache,
                                   jnp.asarray([nxt], jnp.int32))
    assert out_engine == out_ref


def test_slot_reuse_resets_state():
    """A slot reused by a second request must not leak the first
    request's KV cache."""
    api, params = setup()
    eng = Engine(api, params, n_slots=1, max_seq=64)
    eng.submit(Request(rid=0, prompt=[7, 8, 9], max_new=4))
    eng.submit(Request(rid=1, prompt=[7, 8, 9], max_new=4))
    done = eng.run()
    assert len(done) == 2
    assert done[0].out == done[1].out     # identical prompt -> identical out
