"""Training substrate: optimizer, checkpoint, fault tolerance, data."""
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import DataConfig, Stream, batch_at
from repro.models.common import ModelConfig
from repro.models.registry import get_model
from repro.parallel import compression
from repro.train import checkpoint as ckpt
from repro.train import fault_tolerance as ft
from repro.train import optimizer as opt
from repro.train.trainer import Trainer, TrainerConfig

from _proptest import forall, float_arrays

TINY = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                   n_kv_heads=2, d_ff=128, vocab=128, dtype=jnp.float32)
DATA = DataConfig(vocab=128, seq_len=64, global_batch=8, structure=0.9)


# -- optimizer ---------------------------------------------------------------

def test_adamw_decreases_quadratic():
    cfg = opt.AdamWConfig(lr=0.1, warmup_steps=0, weight_decay=0.0,
                          total_steps=100)
    params = dict(w=jnp.ones((4, 4)) * 3.0)
    state = opt.init_state(cfg, params)
    for _ in range(60):
        grads = dict(w=2 * params["w"])            # d/dw ||w||^2
        params, state, _ = opt.apply_updates(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_grad_clipping_bounds_update():
    cfg = opt.AdamWConfig(lr=1.0, warmup_steps=0, grad_clip=1e-3,
                          weight_decay=0.0)
    params = dict(w=jnp.zeros((8,)))
    state = opt.init_state(cfg, params)
    grads = dict(w=jnp.full((8,), 1e6))
    _, _, metrics = opt.apply_updates(cfg, params, grads, state)
    assert float(metrics["grad_norm"]) > 1e5     # reported pre-clip


def test_schedule_warmup_and_decay():
    cfg = opt.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
    lrs = [float(opt.schedule(cfg, jnp.asarray(s))) for s in
           (1, 5, 10, 50, 100)]
    assert lrs[0] < lrs[1] < lrs[2] == pytest.approx(1.0)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(0.1, abs=0.02)


def test_bf16_state_dtype():
    cfg = opt.AdamWConfig(state_dtype=jnp.bfloat16)
    params = dict(w=jnp.ones((4,)))
    state = opt.init_state(cfg, params)
    assert state["m"]["w"].dtype == jnp.bfloat16
    grads = dict(w=jnp.ones((4,)))
    _, state, _ = opt.apply_updates(cfg, params, grads, state)
    assert state["v"]["w"].dtype == jnp.bfloat16


# -- checkpoint ---------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    state = dict(a=jnp.arange(6).reshape(2, 3),
                 nested=dict(b=jnp.ones((4,), jnp.bfloat16)),
                 lst=[jnp.zeros(2), jnp.ones(3)],
                 step=jnp.asarray(7))
    ckpt.save(str(tmp_path), 7, state)
    restored, step = ckpt.restore(str(tmp_path), state)
    assert step == 7
    assert (np.asarray(restored["a"]) == np.asarray(state["a"])).all()
    assert restored["nested"]["b"].dtype == jnp.bfloat16
    assert (np.asarray(restored["lst"][1]) == 1).all()


def test_checkpoint_latest_and_prune(tmp_path):
    for s in (10, 20, 30, 40):
        ckpt.save(str(tmp_path), s, dict(x=jnp.asarray(s)))
    assert ckpt.latest_step(str(tmp_path)) == 40
    ckpt.prune(str(tmp_path), keep=2)
    steps = sorted(d for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert len(steps) == 2
    restored, _ = ckpt.restore(str(tmp_path), dict(x=jnp.asarray(0)))
    assert int(restored["x"]) == 40


def test_checkpoint_atomicity(tmp_path):
    """A .tmp directory must never be visible as a checkpoint."""
    ckpt.save(str(tmp_path), 1, dict(x=jnp.asarray(1)))
    os.makedirs(tmp_path / "step_00000002.tmp" / "arrays")
    assert ckpt.latest_step(str(tmp_path)) == 1


# -- trainer end-to-end --------------------------------------------------------

def test_trainer_learns_and_resumes(tmp_path):
    api = get_model(TINY)
    t = Trainer(api, opt.AdamWConfig(lr=1e-3, warmup_steps=5),
                TrainerConfig(total_steps=30, ckpt_every=15,
                              ckpt_dir=str(tmp_path), log_every=1000),
                log_fn=lambda s: None)
    res = t.fit(Stream(DATA))
    assert res["losses"][-1] < res["losses"][0]
    t2 = Trainer(api, opt.AdamWConfig(lr=1e-3, warmup_steps=5),
                 TrainerConfig(total_steps=35, ckpt_every=0,
                               ckpt_dir=str(tmp_path), log_every=1000),
                 log_fn=lambda s: None)
    assert t2.maybe_resume()
    assert t2.step_idx == 30
    s = Stream(DATA)
    s.seek(30)
    res2 = t2.fit(s)
    assert res2["final_step"] == 35


def test_preemption_checkpoint(tmp_path):
    """SIGTERM mid-run -> checkpoint written, clean exit."""
    api = get_model(TINY)
    t = Trainer(api, opt.AdamWConfig(lr=1e-3),
                TrainerConfig(total_steps=1000, ckpt_every=0,
                              ckpt_dir=str(tmp_path), log_every=10 ** 6),
                log_fn=lambda s: None)

    class Batches:
        def __iter__(self):
            self.it = iter(Stream(DATA))
            self.n = 0
            return self

        def __next__(self):
            self.n += 1
            if self.n == 4:
                os.kill(os.getpid(), signal.SIGTERM)
            return next(self.it)

    res = t.fit(iter(Batches()))
    assert res["final_step"] < 1000
    assert ckpt.latest_step(str(tmp_path)) == res["final_step"]


def test_straggler_watchdog():
    dog = ft.StragglerWatchdog(timeout_factor=2.0, max_flags=2)
    for _ in range(10):
        assert not dog.observe(1.0)
    assert not dog.observe(5.0)     # first flag
    assert dog.observe(5.0)         # second consecutive -> restart


def test_elastic_mesh_planning():
    assert ft.plan_elastic_mesh(256, 16) == (16, 16)
    assert ft.plan_elastic_mesh(240, 16) == (15, 16)
    assert ft.plan_elastic_mesh(255, 16) == (15, 16)
    with pytest.raises(RuntimeError):
        ft.plan_elastic_mesh(8, 16)
    assert ft.plan_elastic_mesh(512, 16, pod_size=256) == (2, 16, 16)


# -- gradient compression -------------------------------------------------------

@forall(n_cases=20, g=float_arrays((32, 16), scale=3.0))
def test_compression_error_feedback_unbiased(g):
    """Over repeated steps with the same gradient, the accumulated
    applied update converges to the true gradient direction (error
    feedback property)."""
    grads = dict(w=jnp.asarray(g))
    ef = compression.init_error_feedback(grads)
    total = jnp.zeros_like(grads["w"])
    n = 24
    for _ in range(n):
        deq, ef = compression.compress_decompress(grads, ef)
        total = total + deq["w"]
    np.testing.assert_allclose(np.asarray(total / n),
                               np.asarray(grads["w"]),
                               atol=np.abs(g).max() / 100 + 1e-5)


def test_quantize_int8_range():
    x = jnp.asarray([-300.0, 0.0, 150.0, 300.0])
    q, s = compression.quantize_int8(x)
    assert q.dtype == jnp.int8
    deq = compression.dequantize_int8(q, s)
    np.testing.assert_allclose(np.asarray(deq), np.asarray(x),
                               atol=float(s) + 1e-6)


# -- data pipeline ---------------------------------------------------------------

def test_data_deterministic_and_seekable():
    b1 = batch_at(DATA, 17)
    b2 = batch_at(DATA, 17)
    assert (b1["tokens"] == b2["tokens"]).all()
    s = Stream(DATA, start=17)
    b3 = next(s)
    assert (b1["tokens"] == b3["tokens"]).all()


def test_data_host_sharding_consistent():
    full = batch_at(DATA, 3)
    lo = batch_at(DATA, 3, host_slice=slice(0, 4))
    hi = batch_at(DATA, 3, host_slice=slice(4, 8))
    assert (np.concatenate([lo["tokens"], hi["tokens"]])
            == full["tokens"]).all()


def test_data_labels_shifted():
    b = batch_at(DATA, 0)
    assert b["tokens"].shape == (8, 64)
    # structure: labels mostly follow the permutation of tokens
    assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).all()
