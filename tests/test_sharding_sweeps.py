"""Device-sharded sweep axes: bit-identical to the single-device path."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.shard import sharded_vmap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_single_device_fallback_is_plain_vmap():
    f = sharded_vmap(lambda x: x * 2 + 1, n_devices=1)
    x = jnp.arange(7, dtype=jnp.int32)
    assert (np.asarray(f(x)) == np.asarray(jax.vmap(
        lambda x: x * 2 + 1)(x))).all()


def test_pytree_batch_and_dict_output():
    f = sharded_vmap(lambda t: dict(s=t[0] + t[1], d=t[0] - t[1]))
    a = jnp.arange(5.0)
    out = f((a, a * 3))
    assert (np.asarray(out["s"]) == np.asarray(a * 4)).all()
    assert out["d"].shape == (5,)


_SHARD_SCRIPT = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    assert jax.device_count() == 4, jax.devices()
    from repro.core.platform import run_frontend
    from repro.core.shard import sharded_vmap
    from repro.core import get_stage
    from repro.traces import make_suite, stack_traces
    from repro.traces.frontend import TraceFrontend
    from repro.traces.replay import VIEW_KEYS

    cfg = get_stage("03-ps-clock", windows=6, warmup=2)
    def one(trace):
        views, outs = run_frontend(cfg, TraceFrontend(
            trace, cfg.workload_config()))
        return dict({k: views[k] for k in VIEW_KEYS},
                    progress=outs.progress)

    # 3 apps on 4 devices: exercises the right-pad + slice path too
    _, traces = make_suite(n=256, names=("stream", "gups", "pointer_chase"))
    batch = stack_traces(traces)
    sharded = jax.device_get(sharded_vmap(one, n_devices=4)(batch))
    single = jax.device_get(sharded_vmap(one, n_devices=1)(batch))
    for k in single:
        a, b = np.asarray(sharded[k]), np.asarray(single[k])
        assert a.shape == b.shape, k
        assert (a == b).all(), (k, a, b)     # BIT-identical, not approx
    print("OK")
""")


def test_shard_map_bit_identical_to_vmap_on_forced_devices():
    """Acceptance: the shard_map sweep path equals the vmap path bit for
    bit.  Runs in a subprocess with 4 forced CPU host devices (the
    device count is fixed at jax import time)."""
    env = dict(os.environ,
               XLA_FLAGS=(os.environ.get("XLA_FLAGS", "")
                          + " --xla_force_host_platform_device_count=4"),
               JAX_PLATFORMS="cpu",
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT],
                          capture_output=True, text=True, env=env,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "OK" in proc.stdout
