"""Serving-traffic oracle: golden grid + percentile references.

Pins the LLM-serving lowering (`repro.traces.llm`) the same way
`tests/test_event_weave.py` pins the kernel traces:

* serving-derived trace replay is **bit-identical** between the dense
  and event weave engines across ddr4_2666 / ddr5_4800 / hbm2e x 1-2
  sockets (serving traces are MSHR-hot, so the event cells run under a
  covering budget — the `full_budget` contract);
* `hist_percentiles` is pinned against a hand-computed log2-histogram
  reference AND recomputed independently at the consumer
  (`benchmarks.serving.cell_percentiles`), so interface-percentile
  regressions are caught where they are reported, not just at the
  unit level;
* the scheduler respects `SlotPool` admission invariants and the
  per-step traffic model is *exactly* the HLO cost model's output.
"""
import dataclasses
import os
import sys

import jax
import numpy as np
import pytest

from repro.configs.registry import get_smoke
from repro.core import get_stage
from repro.core.platform import run_frontend
from repro.obs import hist_percentiles
from repro.traces import (ServeScenario, decode_cost, lower_decode,
                          lower_scenario, replay_suite,
                          request_latencies_ms, serving_terms,
                          simulate_schedule, stack_traces)
from repro.traces.frontend import TraceFrontend
from repro.traces.llm import STREAMS, arrival_steps, step_stream_bytes

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

FAST = dict(windows=6, warmup=2)


def _scenario(model="tinyllama-1.1b", **kw):
    kw.setdefault("arrival", "poisson")
    kw.setdefault("rate", 0.5)
    kw.setdefault("n_requests", 10)
    kw.setdefault("n_slots", 3)
    return ServeScenario(model=get_smoke(model), **kw)


def serving(model="tinyllama-1.1b", **kw):
    """Golden-grid frontend builder over a lowered serving trace."""
    trace, _, _ = lower_scenario(_scenario(model, **kw))

    def build(cfg):
        return lambda: run_frontend(
            cfg, TraceFrontend(trace, cfg.workload_config()))

    build.full_budget = True        # serving replay is MSHR-hot
    return build


def run_pair(stage, preset, frontend, n_sockets=1):
    out = {}
    for weave in ("dense", "event"):
        cfg = get_stage(stage, preset=preset, n_sockets=n_sockets,
                        weave=weave, **FAST)
        if weave == "event" and getattr(frontend, "full_budget", False):
            cfg = dataclasses.replace(
                cfg, weave_events=cfg.clock().ticks_per_window_static)
        out[weave] = jax.device_get(jax.jit(frontend(cfg))())
    return out["dense"], out["event"]


SEMANTIC_VIEWS = ("sim_bw_gbs", "sim_lat_ns", "if_bw_gbs", "if_lat_ns",
                  "app_bw_gbs", "app_lat_ns", "chase_lat_ns",
                  "n_rd", "n_wr", "l_ir_final", "injected")


def assert_bit_identical(dense, event):
    (vd, od), (ve, oe) = dense, event
    for name, a, b in zip(od._fields, od, oe):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"WindowOut.{name} differs between weave engines")
    for key in SEMANTIC_VIEWS:
        np.testing.assert_array_equal(
            np.asarray(vd[key]), np.asarray(ve[key]),
            err_msg=f"view {key!r} differs between weave engines")
    assert int(np.sum(ve["weave_sat"])) == 0, \
        "event budget saturated on a serving golden-grid point"


# every preset x both socket counts, model families varied across cells
GRID = [
    ("10-delay-buffer", "ddr4_2666", ("tinyllama-1.1b", "poisson"), 1),
    ("04-model-correct", "ddr5_4800", ("xlstm-1.3b", "uniform"), 1),
    ("01-baseline", "hbm2e", ("arctic-480b", "burst"), 2),
    ("10-delay-buffer", "ddr5_4800", ("zamba2-2.7b", "poisson"), 2),
]
_IDS = [f"{g[0]}-{g[1]}-{g[2][0]}-{g[3]}s" for g in GRID]


@pytest.mark.parametrize("stage,preset,cell,n_sockets", GRID, ids=_IDS)
def test_serving_replay_bit_identical(stage, preset, cell, n_sockets):
    model, arrival = cell
    frontend = serving(model, arrival=arrival)
    dense, event = run_pair(stage, preset, frontend, n_sockets)
    assert_bit_identical(dense, event)


# ------------------------------------------------- percentile oracle

def test_hist_percentiles_hand_computed():
    """Literal reference: 2 samples in bucket 3 ([8,16)), 2 in bucket
    5 ([32,64)).  p50's target (2.0) lands exactly on bucket 3's
    cumulative boundary -> 8 * (1 + 2/2) = 16.0; p95's target 3.8 is
    0.9 into bucket 5 -> 32 * 1.9 = 60.8; p99 -> 32 * 1.98 = 63.36."""
    h = np.zeros(24)
    h[3] = 2
    h[5] = 2
    got = hist_percentiles(h, (0.5, 0.95, 0.99))
    np.testing.assert_allclose(got, [16.0, 60.8, 63.36], rtol=1e-12)
    # window/channel leading axes reduce by summation: splitting the
    # same counts across planes must not move any percentile
    split = np.zeros((2, 3, 24))
    split[0, 1, 3] = 2
    split[1, 2, 5] = 2
    np.testing.assert_allclose(
        hist_percentiles(split, (0.5, 0.95, 0.99)), got, rtol=1e-12)


def test_percentiles_at_the_consumer():
    """The benchmark's reported if_p* derive from the replayed
    telemetry histograms exactly as an independent reimplementation
    says they should — a `hist_percentiles` regression surfaces in
    BENCH_serve.json numbers, not only in unit tests."""
    sys.path.insert(0, ROOT)
    try:
        from benchmarks.serving import cell_percentiles
    finally:
        sys.path.remove(ROOT)
    trace, _, _ = lower_scenario(_scenario())
    cfg = get_stage("10-delay-buffer", preset="ddr5_4800", telemetry=True,
                    **FAST)
    cfg = dataclasses.replace(
        cfg, weave_events=cfg.clock().ticks_per_window_static)
    out = replay_suite(cfg, stack_traces([trace]))
    got = cell_percentiles(out, 0)

    # independent quantile-from-log2-histogram reimplementation
    h = np.asarray(out["tele_hist_if_ps"][0], np.float64)
    h = h.reshape(-1, h.shape[-1]).sum(axis=0)
    cum, total = np.cumsum(h), h.sum()
    assert total > 0
    for q, key in ((0.5, "if_p50_ns"), (0.95, "if_p95_ns"),
                   (0.99, "if_p99_ns")):
        b = next(i for i, c in enumerate(cum) if c >= q * total)
        prev = cum[b - 1] if b else 0.0
        frac = min(max((q * total - prev) / max(h[b], 1e-12), 0.0), 1.0)
        want_ns = (2.0 ** b) * (1.0 + frac) / 1e3
        np.testing.assert_allclose(got[key], want_ns, rtol=1e-9)


def test_request_latencies_byte_weighted():
    """Request latency = service time of the request's step span,
    byte-weighted: a hand-built schedule with known per-step bytes."""
    scn = _scenario(arrival="burst", n_requests=4, n_slots=2)
    trace, sched, info = lower_scenario(scn)
    lat = request_latencies_ms(sched, info, runtime_ms=10.0)
    assert lat.shape == (4,)
    assert (lat > 0).all()
    cum = np.concatenate([[0], np.asarray(info["cum_bytes"], np.float64)])
    for r, l in zip(sched.requests, lat):
        want = 10.0 * (cum[r.finish + 1] - cum[r.arrival]) / cum[-1]
        np.testing.assert_allclose(l, want, rtol=1e-12)
    # burst arrivals all land at step 0, so the last request to finish
    # spans the whole schedule -> its latency is the full runtime
    assert all(r.arrival == 0 for r in sched.requests)
    last = max(range(4), key=lambda i: sched.requests[i].finish)
    np.testing.assert_allclose(lat[last], 10.0, rtol=1e-12)


# ------------------------------------------------ scheduler invariants

@pytest.mark.parametrize("arrival", ["poisson", "uniform", "burst"])
def test_schedule_slotpool_invariants(arrival):
    scn = _scenario(arrival=arrival, n_requests=16, n_slots=4)
    sched = simulate_schedule(scn)
    # occupancy bounded by the pool, every step accounted
    assert (sched.n_active <= scn.n_slots).all()
    assert (sched.ctx_sum >= 0).all()
    assert sched.steps == len(sched.ctx_sum)
    by_rid = sorted(sched.requests, key=lambda r: r.rid)
    for r in by_rid:
        assert 0 <= r.arrival <= r.admit <= r.finish
        # admit-to-finish span is exactly the token count
        assert r.finish - r.admit + 1 == r.total
    # FIFO: admission order follows arrival order (rid breaks ties)
    admits = [r.admit for r in by_rid]
    assert admits == sorted(admits)
    # total work conserved: sum of busy slot-steps == sum of tokens
    assert int(sched.n_active.sum()) == sum(r.total for r in by_rid)


def test_arrival_distributions():
    base = _scenario(n_requests=32, rate=0.5)
    pois = arrival_steps(base)
    assert (np.diff(pois) >= 0).all() and pois[0] >= 0
    uni = arrival_steps(dataclasses.replace(base, arrival="uniform"))
    np.testing.assert_array_equal(uni, np.arange(32) * 2)
    bur = arrival_steps(dataclasses.replace(base, arrival="burst"))
    assert (bur == 0).all()
    with pytest.raises(ValueError):
        arrival_steps(dataclasses.replace(base, arrival="pareto"))
    with pytest.raises(ValueError):
        arrival_steps(dataclasses.replace(base, rate=0.0))
    # determinism: same seed -> same process
    np.testing.assert_array_equal(pois, arrival_steps(base))


# ------------------------------------------- exact traffic accounting

def test_bilinear_model_is_exact():
    """`serving_terms` is a *model* only in form: at any occupancy it
    reproduces `decode_cost`'s per-stream bytes exactly, so the
    serving trace is the HLO cost model evaluated per step."""
    for model in ("tinyllama-1.1b", "arctic-480b", "zamba2-2.7b"):
        cfg = get_smoke(model)
        terms = serving_terms(cfg)
        for B, S in ((1, 1), (3, 7), (6, 250)):
            want = decode_cost(cfg, B, S)["stream_bytes"]
            got = step_stream_bytes(terms, B, B * S)
            assert got == {s: want[s] for s in STREAMS}, (model, B, S)


def test_serving_trace_conserves_bytes():
    trace, _, info = lower_scenario(_scenario(), target_step_lines=256)
    emitted = int(trace.length) * info["line_bytes"] * info["shard"]
    tol = len(STREAMS) * info["line_bytes"] * info["shard"]
    assert abs(emitted - info["bytes_modeled"]) <= tol
    assert info["bytes_modeled"] == sum(info["stream_bytes"].values())
    assert info["bytes_modeled"] == sum(info["phase_bytes"].values())


def test_lower_decode_step_scaling():
    """steps=k emits ~k x the lines of steps=1 at fixed shard."""
    cfg = get_smoke("qwen2-72b")
    _, i1 = lower_decode(cfg, 2, 64, steps=1, target_lines=1024)
    t3, i3 = lower_decode(cfg, 2, 64, steps=3, target_lines=1024)
    assert i3["bytes_modeled"] == 3 * i1["bytes_modeled"]
    emitted = int(t3.length) * i3["line_bytes"] * i3["shard"]
    tol = len(STREAMS) * i3["line_bytes"] * i3["shard"]
    assert abs(emitted - i3["bytes_modeled"]) <= tol
