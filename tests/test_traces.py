"""Trace subsystem: representation, kernels, replay, frontend parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import get_stage, run_point
from repro.core.workload import CAP_DEMAND
from repro.traces import (KERNELS, Trace, anchor_suite_ms, make_suite,
                          make_trace, replay_suite, stack_traces,
                          trace_stats)
from repro.traces.kernels import mess_traffic

FAST = dict(windows=24, warmup=8)


# ---------------------------------------------------------------- traces

def test_kernel_generators_emit_valid_traces():
    names, traces = make_suite(n=1024)
    assert set(names) == set(KERNELS)
    for nm, t in zip(names, traces):
        st = trace_stats(t)
        assert st["accesses"] == 1024, nm
        # padded for windowed dynamic_slice
        assert t.n_slots >= 1024 + CAP_DEMAND, nm
        # deltas reconstruct to lines inside the footprint
        lines = np.cumsum(np.asarray(t.delta)[:1024]) % int(
            t.footprint_lines)
        assert (lines >= 0).all() and (lines < int(t.footprint_lines)).all()


def test_kernel_character():
    """Each kernel carries its DAMOV-class signature."""
    _, (stream_t, gups_t, _, _, chase_t, bfs_t) = make_suite(n=1024)
    assert trace_stats(stream_t)["write_frac"] == pytest.approx(1 / 3,
                                                                abs=0.02)
    assert trace_stats(gups_t)["write_frac"] == pytest.approx(0.5, abs=0.01)
    assert trace_stats(chase_t)["dep_frac"] > 0.99
    assert 0.1 < trace_stats(bfs_t)["dep_frac"] < 0.3
    assert trace_stats(stream_t)["dep_frac"] == 0.0


def test_make_trace_validates():
    with pytest.raises(ValueError):
        make_trace([1, 2], [0], [0], 1024)        # length mismatch
    with pytest.raises(ValueError):
        make_trace([1], [0], [0], 0)              # bad footprint


def test_stack_traces_pads_to_common_length():
    a = make_trace(np.ones(100), np.zeros(100), np.zeros(100), 1 << 16)
    b = make_trace(np.ones(500), np.zeros(500), np.zeros(500), 1 << 16)
    batch = stack_traces([a, b])
    assert batch.delta.shape[0] == 2
    assert batch.delta.shape[1] == b.n_slots
    assert list(np.asarray(batch.length)) == [100, 500]


# ---------------------------------------------------------------- replay

@pytest.fixture(scope="module")
def suite_result():
    names, traces = make_suite(n=1024)
    cfg = get_stage("04-model-correct", **FAST)
    return names, traces, replay_suite(cfg, stack_traces(traces))


def test_batched_replay_all_apps(suite_result):
    names, _, out = suite_result
    assert out["sim_bw_gbs"].shape == (len(names),)
    assert (out["n_rd"] > 0).all()
    assert (out["runtime_ms"] > 0).all()
    assert np.isfinite(out["runtime_ms"]).all()


def test_latency_bound_app_is_slowest(suite_result):
    names, _, out = suite_result
    rt = dict(zip(names, out["runtime_ms"]))
    assert rt["pointer_chase"] > 2 * rt["stream"]
    # and it barely uses bandwidth
    bw = dict(zip(names, out["sim_bw_gbs"]))
    assert bw["pointer_chase"] < 0.5 * bw["stream"]


def test_short_trace_finishes_and_runtime_counts_windows():
    tiny = make_trace(np.ones(64), np.zeros(64), np.zeros(64), 1 << 16)
    cfg = get_stage("03-ps-clock", windows=16, warmup=4)
    out = replay_suite(cfg, stack_traces([tiny]))
    assert bool(out["done"][0])
    assert out["runtime_windows"][0] <= 4


def test_anchor_runtimes_are_ordered():
    names, traces = make_suite(n=1024)
    anch = dict(zip(names, anchor_suite_ms(traces)))
    # real machine: latency-bound >> bandwidth-bound
    assert anch["pointer_chase"] > 3 * anch["stream"]
    assert all(a > 0 for a in anch.values())


def test_baseline_decoupling_hides_latency_bound_slowdown():
    """The paper's claim on real access patterns: the uncorrected app
    view replays a pointer chase far too fast; stage 04 recouples it."""
    _, traces = make_suite(n=1024, names=("stream", "pointer_chase"))
    batch = stack_traces(traces)
    base = replay_suite(get_stage("01-baseline", **FAST), batch)
    corr = replay_suite(get_stage("04-model-correct", **FAST), batch)
    ratio_base = base["runtime_ms"][1] / base["runtime_ms"][0]
    ratio_corr = corr["runtime_ms"][1] / corr["runtime_ms"][0]
    assert ratio_corr > 1.3 * ratio_base


# ------------------------------------------------- frontend cross-check

def test_trace_frontend_matches_mess_frontend():
    """Acceptance: identical traffic through both frontends -> the
    views agree within tolerance.

    `mess_traffic` emits the pace generator's own pattern (64-line
    sequential segments at scattered bases) as a trace; replayed at
    saturation it must reproduce the Mess sweep point (pace=64) the
    native frontend produces, in all three views.
    """
    cfg = get_stage("04-model-correct", windows=32, warmup=8)
    mess = jax.jit(lambda p, w: run_point(cfg, p, w))(
        jnp.int32(64), jnp.int32(0))
    mess = {k: float(v) for k, v in mess.items()}

    trace = mess_traffic(n=60000, write_num=0)
    out = replay_suite(cfg, stack_traces([trace]))

    assert out["sim_bw_gbs"][0] == pytest.approx(
        mess["sim_bw_gbs"], rel=0.15)
    assert out["sim_lat_ns"][0] == pytest.approx(
        mess["sim_lat_ns"], rel=0.25)
    assert out["if_bw_gbs"][0] == pytest.approx(mess["if_bw_gbs"], rel=0.15)
    assert out["if_lat_ns"][0] == pytest.approx(mess["if_lat_ns"], rel=0.25)
    assert out["app_lat_ns"][0] == pytest.approx(
        mess["app_lat_ns"], rel=0.25)


def test_trace_frontend_write_mix_matches_mess():
    cfg = get_stage("03-ps-clock", windows=24, warmup=8)
    mess = jax.jit(lambda p, w: run_point(cfg, p, w))(
        jnp.int32(64), jnp.int32(21))
    trace = mess_traffic(n=60000, write_num=21)
    out = replay_suite(cfg, stack_traces([trace]))
    # write fraction carried through to the served mix
    mess_wr = float(mess["n_wr"]) / float(mess["n_rd"] + mess["n_wr"])
    tr_wr = out["n_wr"][0] / (out["n_rd"][0] + out["n_wr"][0])
    assert tr_wr == pytest.approx(mess_wr, abs=0.05)
    assert out["sim_bw_gbs"][0] == pytest.approx(
        float(mess["sim_bw_gbs"]), rel=0.2)


# ------------------------------------------- LLM lowering conservation

def test_llm_lowering_conserves_bytes_all_configs():
    """For EVERY registered model config, the lowered decode trace
    conserves `hlo_cost.analyze` bytes within line-rounding: per
    traffic stream the emitted line count is the floor of the exact
    byte total over one line's quantum, so the whole trace is within
    one line per stream.  (`decode_cost` itself raises if the
    renderer's mirrored accounting drifts from `analyze` by a single
    byte, so this also re-verifies the renderer on every config.)"""
    from _proptest import forall, integers
    from repro.configs.registry import ARCH_ORDER, get_config
    from repro.traces import decode_cost, lower_decode
    from repro.traces.llm import STREAMS

    for name in ARCH_ORDER:
        cfg = get_config(name)

        @forall(n_cases=4, seed=sum(map(ord, name)),
                batch=integers(1, 8), seq=integers(1, 2048))
        def check(batch, seq):
            cost = decode_cost(cfg, batch, seq)
            trace, info = lower_decode(cfg, batch, seq,
                                       target_lines=512)
            assert info["bytes_modeled"] == cost["bytes"]
            emitted = int(trace.length) * info["line_bytes"] \
                * info["shard"]
            tol = len(STREAMS) * info["line_bytes"] * info["shard"]
            assert abs(emitted - info["bytes_modeled"]) <= tol, \
                (name, batch, seq, emitted, info["bytes_modeled"])

        check()
