"""Per-assigned-architecture smoke tests (reduced configs).

Required by the assignment: instantiate a REDUCED config of the same
family and run one forward + one train step on CPU, asserting output
shapes and the absence of NaNs.  The FULL configs are exercised only
via the dry-run (ShapeDtypeStruct, no allocation) — asserted here via
eval_shape parameter-count checks against the published sizes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as cfgs
from repro.models.registry import count_params, get_model
from repro.train import optimizer as opt
from repro.train.step import build_train_step

ARCHS = list(cfgs.ARCH_ORDER)


def make_batch(api, b=2, s=16):
    cfg = api.cfg
    rng = np.random.default_rng(1)
    batch = dict(
        tokens=jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        labels=jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32))
    if api.needs_ctx:
        batch["ctx"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_ctx_tokens, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = cfgs.get_smoke(arch)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(api)
    logits = api.forward(params, batch)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"NaNs in {arch} logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = cfgs.get_smoke(arch)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    ocfg = opt.AdamWConfig(lr=1e-3)
    ostate = opt.init_state(ocfg, params)
    step = jax.jit(build_train_step(api, ocfg, accum=2))
    batch = make_batch(api, b=4)
    new_params, ostate, metrics = step(params, ostate, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), params, new_params)
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = cfgs.get_smoke(arch)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(api)
    cache = api.init_cache(2, 32)
    if api.needs_ctx:
        cache = api.fill_ctx(params, cache, batch["ctx"])
    logits, cache = api.decode(params, cache, batch["tokens"][:, 0])
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert int(cache["length"][0]) == 1


#: published parameter counts (tolerance: naming/FFN-variant slack)
EXPECTED_PARAMS = {
    "tinyllama-1.1b": (1.0e9, 1.3e9),
    "minitron-8b": (7.5e9, 10.5e9),
    "qwen2-72b": (67e9, 76e9),
    "deepseek-7b": (6.5e9, 7.8e9),
    # our mLSTM keeps full dh x dh per-head q/k/v (official uses a
    # narrower qk dim); documented in DESIGN.md §param-counts
    "xlstm-1.3b": (1.0e9, 2.1e9),
    "llama-3.2-vision-11b": (8.5e9, 11.5e9),
    "arctic-480b": (430e9, 500e9),
    "grok-1-314b": (290e9, 330e9),
    "whisper-large-v3": (1.2e9, 2.2e9),
    "zamba2-2.7b": (2.2e9, 3.2e9),
}


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count(arch):
    """The FULL config's abstract parameter count lands in the
    published ballpark (no allocation — eval_shape only)."""
    cfg = cfgs.get_config(arch)
    api = get_model(cfg)
    struct = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    n = count_params(struct)
    lo, hi = EXPECTED_PARAMS[arch]
    assert lo <= n <= hi, f"{arch}: {n / 1e9:.2f}B params"
