"""Cross-simulator clocking: aggregated models vs Listing 1(b) oracle."""
import pytest

from repro.core.clocking import (CLOCK_MODES, make_clock,
                                 reference_listing_1b)
from repro.core.timing import DEFAULT_PLATFORM

from _proptest import forall, integers


def test_picosecond_matches_listing_1b_exactly():
    """The aggregated ClockModel reproduces the paper's per-cycle loop."""
    clock = make_clock("picosecond")
    traj = reference_listing_1b(5000)
    for cycle1, (cpu_ps, dram_ps, dram_cycle) in enumerate(traj, start=1):
        assert cpu_ps == cycle1 * clock.cpu_ps_per_clk
        # Listing 1b: after the while loop, dramCycle is the first tick
        # whose time has caught up with cpuPs
        assert clock.cycle_to_tick(cycle1) == dram_cycle, cycle1
        assert dram_ps == dram_cycle * clock.dram_ps_per_clk


def test_frequency_ratios():
    p = DEFAULT_PLATFORM
    assert p.freq_ratio_ceil == 2
    assert abs(p.freq_ratio_exact - 1.575) < 1e-3


@pytest.mark.parametrize("mode", CLOCK_MODES)
def test_ticks_per_window_bounds(mode):
    clock = make_clock(mode)
    for w in range(50):
        n = clock.active_ticks_in_window(w)
        assert 0 < n <= clock.ticks_per_window_static


def test_broken_noscale_runs_dram_at_cpu_speed():
    clock = make_clock("broken_noscale")
    # one tick per cpu cycle; CPU perceives each tick as 476 ps
    assert clock.cycle_to_tick(1000) == 1000
    assert clock.tick_to_cpu_ps(1000) == 1000 * 476
    # the memory simulator itself thinks 750 ps passed per tick: the
    # CPU sees memory running 1.575x too fast
    assert clock.tick_to_sim_ps(1000) == 750000


def test_damov_ceil_runs_dram_at_half_cpu_speed():
    clock = make_clock("damov_ceil")
    assert clock.cycle_to_tick(1000) == 500     # freqRatio = 2
    # => effective memory frequency 1.05 GHz instead of 1.333 GHz


@forall(n_cases=100, cycle=integers(1, 10 ** 6))
def test_cycle_to_tick_monotone_and_exact(cycle):
    clock = make_clock("picosecond")
    t0 = clock.cycle_to_tick(cycle)
    t1 = clock.cycle_to_tick(cycle + 1)
    assert t0 <= t1
    # tick time must have caught up with the cycle's cpu time (the
    # while-loop postcondition of Listing 1b)
    assert t0 * 750 >= cycle * 476
    assert (t0 - 1) * 750 < cycle * 476


def test_bandwidth_ratio_between_modes():
    """The paper's numbers: broken interface sees 1.575x bandwidth,
    DAMOV ceil sees 0.7875x (=1.05/1.333) of the true rate."""
    ps = make_clock("picosecond")
    broken = make_clock("broken_noscale")
    ceil = make_clock("damov_ceil")
    n = 10 ** 6
    # ticks available per unit of CPU time determine service rate
    assert broken.cycle_to_tick(n) / ps.cycle_to_tick(n) == pytest.approx(
        1.575, rel=1e-3)
    assert ceil.cycle_to_tick(n) / ps.cycle_to_tick(n) == pytest.approx(
        0.7875, rel=1e-3)
