"""Scenario fuzzer: every random run's command stream is legal.

Each seed draws a random scenario — preset, ladder stage, workload
(Mess operating point, a 1–3 app trace mix with random kernels,
lengths, and per-core phase offsets, or an LLM-serving trace from a
random model config x arrival process), socket count, weave engine, and
occasionally a synthetic device geometry — replays it with
``StageConfig(cmd_trace=True)``, and pushes the recorded stream
through the full `repro.oracle.check_stream` rule set.  Any violation
is a controller-model bug (fix `repro.core.dram`, never the checker).

Tier-1 runs a fast 8-seed smoke; nightly CI scales it with
``REPRO_FUZZ_N`` (e.g. 200).  Seeds are deterministic: a failing seed
reproduces with ``REPRO_FUZZ_N=<seed+1> pytest -k <seed>``.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import get_stage
from repro.core.platform import run_frontend
from repro.core.presets import PRESETS
from repro.core.workload import MessFrontend
from repro.oracle import check_stream, extract_stream
from repro.traces import assign_traces, split_cores
from repro.traces.frontend import TraceFrontend
from repro.traces.kernels import (bfs_frontier, gups, pointer_chase,
                                  spmv, stencil3d, stream)

N_SEEDS = int(os.environ.get("REPRO_FUZZ_N", "8"))

KERNELS = (stream, gups, stencil3d, spmv, pointer_chase, bfs_frontier)

#: stages drawn for standard presets; geometry-variant draws stick to
#: pre-addrmap stages (the synthetic channel counts are not what the
#: stage-05+ decoders were pinned against)
STAGES = ("01-baseline", "02-clock-scale", "03-ps-clock",
          "04-model-correct", "05-addrmap", "07-prefetch",
          "08-dramsim3", "09-ramulator2", "10-delay-buffer")
GEO_STAGES = ("01-baseline", "02-clock-scale", "04-model-correct")


def draw_scenario(rng):
    """One random scenario; returns (description, cfg, frontend_fn)."""
    preset = str(rng.choice(list(PRESETS)))
    geo = rng.random() < 0.25
    stage = str(rng.choice(GEO_STAGES if geo else STAGES))
    n_sockets = 2 if (not geo and rng.random() < 0.2) else 1
    windows, warmup = 4, 1
    weave = str(rng.choice(["dense", "event"]))
    cfg = get_stage(stage, preset=preset, n_sockets=n_sockets,
                    windows=windows, warmup=warmup, weave=weave,
                    cmd_trace=True)
    if geo:
        # a synthetic device: the checker must hold off-preset too
        d = dataclasses.replace(
            cfg.platform.dram,
            n_channels=int(rng.choice([2, 3, 4, 6])),
            ranks_per_channel=int(rng.choice([1, 2])),
            banks_per_rank=int(rng.choice([8, 16])))
        cfg = dataclasses.replace(
            cfg, platform=dataclasses.replace(cfg.platform, dram=d))

    kind = rng.random()
    if kind < 0.35:
        pace = int(rng.integers(1, 49))
        wr = int(rng.integers(0, 65))
        desc = f"mess p={pace} wr={wr}"

        def frontend(cfg):
            fe = MessFrontend(jnp.int32(pace), jnp.int32(wr),
                              cfg.workload_config())
            return lambda: run_frontend(cfg, fe)
    elif kind < 0.65:
        # LLM-serving traffic: random model config x arrival process x
        # pool size lowered via repro.traces.llm — the JEDEC checker
        # and differential oracle cover the serving perspective too
        from repro.configs.registry import ARCH_ORDER, get_smoke
        from repro.traces import ServeScenario, lower_scenario
        model = str(rng.choice(ARCH_ORDER))
        arrival = str(rng.choice(["poisson", "uniform", "burst"]))
        scn = ServeScenario(
            model=get_smoke(model), arrival=arrival,
            rate=float(rng.choice([0.25, 0.5, 1.0, 2.0])),
            n_requests=int(rng.integers(4, 17)),
            n_slots=int(rng.integers(1, 7)),
            seed=int(rng.integers(0, 1 << 16)))
        trace, _, _ = lower_scenario(scn)
        desc = f"serve {model} {arrival} r={scn.rate} s={scn.n_slots}"
        # serving replay is MSHR-hot: covering event budget
        if cfg.weave == "event":
            cfg = dataclasses.replace(
                cfg, weave_events=cfg.clock().ticks_per_window_static)

        def frontend(cfg):
            return lambda: run_frontend(
                cfg, TraceFrontend(trace, cfg.workload_config()))
    else:
        n_apps = int(rng.integers(1, 4))
        picks = rng.choice(len(KERNELS), size=n_apps, replace=False)
        apps = [KERNELS[i](n=int(rng.integers(64, 513)),
                           seed=int(rng.integers(0, 1 << 16)))
                for i in picks]
        desc = "mix " + "+".join(KERNELS[i].__name__ for i in picks)
        # full event budget: MSHR-throttled replay is saturation-hot
        if cfg.weave == "event":
            cfg = dataclasses.replace(
                cfg, weave_events=cfg.clock().ticks_per_window_static)

        def frontend(cfg):
            wcfg = cfg.workload_config()
            offs = [int(rng.integers(0, 4096))
                    for _ in range(wcfg.n_cores)]
            m = assign_traces(apps, split_cores(n_apps, wcfg.n_cores),
                              phase_offsets=offs)
            return lambda: run_frontend(cfg, TraceFrontend(m, wcfg))

    desc = (f"{preset}/{stage}/{cfg.weave}/{n_sockets}s "
            f"C={cfg.platform.dram.n_channels} {desc}")
    return desc, cfg, frontend


@pytest.mark.parametrize("seed", range(N_SEEDS))
def test_fuzzed_stream_is_protocol_legal(seed):
    rng = np.random.default_rng(0xC0FFEE + seed)
    desc, cfg, frontend = draw_scenario(rng)
    views, _ = jax.device_get(jax.jit(frontend(cfg))())
    s = extract_stream(views, cfg.platform.dram)
    assert len(s) > 0, desc
    end_tick = int(cfg.clock().window_end_tick(cfg.windows - 1))
    rep = check_stream(s, end_tick=end_tick)
    assert rep.ok, f"{desc}: {rep.summary()}\n{rep.violations[:5]}"
