"""Address mapping properties (simple + Skylake XOR) and kernel parity."""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import addrmap
from repro.core.presets import platform_for

from _proptest import forall, uint32_arrays


@forall(n_cases=30, lines=uint32_arrays(2048))
def test_fields_in_range_simple(lines):
    dec = addrmap.decode(jnp.asarray(lines), "simple")
    assert addrmap.check_fields(dec)


@forall(n_cases=30, lines=uint32_arrays(2048))
def test_fields_in_range_xor(lines):
    dec = addrmap.decode(jnp.asarray(lines), "skylake_xor")
    assert addrmap.check_fields(dec)


def test_mapping_is_deterministic():
    lines = jnp.arange(10000, dtype=jnp.uint32)
    a = addrmap.decode(lines, "skylake_xor")
    b = addrmap.decode(lines, "skylake_xor")
    for f in a._fields:
        assert (np.asarray(getattr(a, f)) == np.asarray(getattr(b, f))).all()


def test_channel_balance():
    """Both mappings must spread a large window uniformly-ish over the
    6 channels (Mess traffic assumes this)."""
    lines = jnp.arange(6 * 4096, dtype=jnp.uint32)
    for mapping in ("simple", "skylake_xor"):
        ch = np.asarray(addrmap.decode(lines, mapping).channel)
        counts = np.bincount(ch, minlength=6)
        assert counts.min() > 0.5 * counts.mean(), (mapping, counts)


def test_xor_scatters_streams_simple_does_not():
    """The paper's Fig. 6a mechanism: a sequential stream stays in one
    row under the simple mapping far longer than under the XOR map."""
    lines = jnp.arange(128, dtype=jnp.uint32) * 6  # one channel, simple
    simple = addrmap.decode(lines, "simple")
    xor = addrmap.decode(lines, "skylake_xor")
    n_banks_simple = len(np.unique(np.asarray(simple.flat_bank)))
    n_banks_xor = len(np.unique(np.asarray(xor.flat_bank)))
    assert n_banks_simple <= 2
    assert n_banks_xor > 4


_BASE = platform_for("ddr4_2666").dram


def _geometry(rng, *, xor_fold: bool):
    """A random synthetic device geometry (encodable when xor_fold)."""
    if xor_fold:
        cb = int(rng.integers(0, 3))
        bb = int(rng.choice([2, 3, 4]))
        lb = int(rng.integers(2, 8 - cb - bb + 1))
        C, B, lpr = 1 << cb, 1 << bb, 1 << lb
        R = int(rng.integers(1, 3))
        rows = 1 << int(rng.integers(9, 15))
    else:
        C = int(rng.integers(1, 9))
        R = int(rng.integers(1, 3))
        B = int(rng.choice([4, 8, 16, 32]))
        lpr = int(rng.choice([16, 32, 64, 128]))
        rows = 1 << int(rng.integers(8, 15))
    return dataclasses.replace(
        _BASE, n_channels=C, ranks_per_channel=R, banks_per_rank=B,
        bank_groups=min(4, B), rows_per_bank=rows,
        cols_per_row=lpr * _BASE.line_bytes // 8)


def _fields(rng, d, n=1024):
    """Random in-range decoded fields for device ``d``."""
    f = lambda hi: rng.integers(0, hi, size=n).astype(np.int32)
    return addrmap.DecodedAddr(
        channel=f(d.n_channels), rank=f(d.ranks_per_channel),
        bank=f(d.banks_per_rank), row=f(d.rows_per_bank),
        col=f(d.lines_per_row))


@forall(n_cases=40, d=lambda rng: _geometry(rng, xor_fold=False),
        lines=uint32_arrays(1024))
def test_encode_simple_round_trips_lines(d, lines):
    """encode(decode(line)) == line for any line within capacity, on
    random geometries (`decode_simple` truncates the row beyond it)."""
    cap = (d.n_channels * d.lines_per_row * d.ranks_per_channel
           * d.banks_per_rank * d.rows_per_bank)
    lines = (lines % min(cap, 1 << 32)).astype(np.uint32)
    dec = addrmap.decode_simple(lines, xp=np, dram=d)
    enc = addrmap.encode_simple(dec, d)
    np.testing.assert_array_equal(enc, lines)


@forall(n_cases=40, case_seed=lambda rng: int(rng.integers(0, 1 << 30)))
def test_encode_round_trips_fields(case_seed):
    """decode(encode(fields)) == fields for in-range fields, both the
    simple packer and the XOR-fold solver, on random geometries."""
    rng = np.random.default_rng(case_seed)
    for xor_fold in (False, True):
        d = _geometry(rng, xor_fold=xor_fold)
        dec = _fields(rng, d)
        if xor_fold:
            assert addrmap.xor_fold_encodable(d) is None
            enc = addrmap.encode_xor_fold(dec, d)
            out = addrmap.decode_xor_fold(enc, d, xp=np)
        else:
            enc = addrmap.encode_simple(dec, d)
            out = addrmap.decode_simple(enc, xp=np, dram=d)
        for name in dec._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(out, name)),
                np.asarray(getattr(dec, name)),
                err_msg=f"{'xor_fold' if xor_fold else 'simple'} "
                        f"field {name}")


def test_encode_xor_fold_refuses_real_presets():
    """No shipped preset is XOR-fold-encodable; the solver must say
    why instead of silently mis-encoding."""
    for preset in ("ddr4_2666", "ddr5_4800", "hbm2e"):
        d = platform_for(preset).dram
        reason = addrmap.xor_fold_encodable(d)
        assert isinstance(reason, str) and reason
        with pytest.raises(ValueError, match="not xor_fold-encodable"):
            addrmap.encode_xor_fold(_fields(np.random.default_rng(0), d), d)


def test_kernel_matches_reference():
    from repro.kernels.addr_decode import decode_skylake, decode_reference
    rng = np.random.default_rng(7)
    lines = jnp.asarray(rng.integers(0, 2 ** 32, 5000, dtype=np.uint32))
    d = decode_skylake(lines)
    r = decode_reference(lines)
    for f in d._fields:
        assert (np.asarray(getattr(d, f)) == np.asarray(getattr(r, f))).all()
