"""Address mapping properties (simple + Skylake XOR) and kernel parity."""
import numpy as np
import jax.numpy as jnp

from repro.core import addrmap

from _proptest import forall, uint32_arrays


@forall(n_cases=30, lines=uint32_arrays(2048))
def test_fields_in_range_simple(lines):
    dec = addrmap.decode(jnp.asarray(lines), "simple")
    assert addrmap.check_fields(dec)


@forall(n_cases=30, lines=uint32_arrays(2048))
def test_fields_in_range_xor(lines):
    dec = addrmap.decode(jnp.asarray(lines), "skylake_xor")
    assert addrmap.check_fields(dec)


def test_mapping_is_deterministic():
    lines = jnp.arange(10000, dtype=jnp.uint32)
    a = addrmap.decode(lines, "skylake_xor")
    b = addrmap.decode(lines, "skylake_xor")
    for f in a._fields:
        assert (np.asarray(getattr(a, f)) == np.asarray(getattr(b, f))).all()


def test_channel_balance():
    """Both mappings must spread a large window uniformly-ish over the
    6 channels (Mess traffic assumes this)."""
    lines = jnp.arange(6 * 4096, dtype=jnp.uint32)
    for mapping in ("simple", "skylake_xor"):
        ch = np.asarray(addrmap.decode(lines, mapping).channel)
        counts = np.bincount(ch, minlength=6)
        assert counts.min() > 0.5 * counts.mean(), (mapping, counts)


def test_xor_scatters_streams_simple_does_not():
    """The paper's Fig. 6a mechanism: a sequential stream stays in one
    row under the simple mapping far longer than under the XOR map."""
    lines = jnp.arange(128, dtype=jnp.uint32) * 6  # one channel, simple
    simple = addrmap.decode(lines, "simple")
    xor = addrmap.decode(lines, "skylake_xor")
    n_banks_simple = len(np.unique(np.asarray(simple.flat_bank)))
    n_banks_xor = len(np.unique(np.asarray(xor.flat_bank)))
    assert n_banks_simple <= 2
    assert n_banks_xor > 4


def test_kernel_matches_reference():
    from repro.kernels.addr_decode import decode_skylake, decode_reference
    rng = np.random.default_rng(7)
    lines = jnp.asarray(rng.integers(0, 2 ** 32, 5000, dtype=np.uint32))
    d = decode_skylake(lines)
    r = decode_reference(lines)
    for f in d._fields:
        assert (np.asarray(getattr(d, f)) == np.asarray(getattr(r, f))).all()
