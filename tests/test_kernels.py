"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _proptest import forall, int32_grid, integers

RNG = np.random.default_rng(42)


# -- flash attention ---------------------------------------------------------

SHAPES = [
    # b, hq, hkv, sq, sk, d, causal
    (2, 4, 4, 128, 128, 64, False),
    (2, 4, 2, 128, 128, 64, True),
    (1, 8, 1, 200, 200, 64, True),
    (2, 4, 1, 64, 384, 128, True),
    (1, 2, 2, 1, 300, 80, True),       # decode
    (1, 4, 2, 257, 512, 32, True),     # non-aligned q
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", SHAPES)
def test_flash_attention_vs_reference(shape, dtype):
    from repro.kernels.flash_attention import flash_attention, mha_reference
    b, hq, hkv, sq, sk, d, causal = shape
    q = jnp.asarray(RNG.standard_normal((b, hq, sq, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, hkv, sk, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, hkv, sk, d)), dtype)
    o = flash_attention(q, k, v, causal=causal)
    r = mha_reference(q, k, v, causal=causal)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_matches_model_attention_path():
    """The kernel and the model's jnp chunked path agree."""
    from repro.kernels.flash_attention import flash_attention
    from repro.models import common as cm
    q = jnp.asarray(RNG.standard_normal((2, 150, 8, 64)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, 150, 2, 64)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, 150, 2, 64)), jnp.float32)
    jnp_o = cm._chunked_attention(q, k, v, causal=True, chunk=64)
    pl_o = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                           v.transpose(0, 2, 1, 3),
                           causal=True).transpose(0, 2, 1, 3)
    # jnp path ships bf16 probabilities (§Perf iter 1); the Pallas
    # kernel keeps fp32 probs in VMEM -> bf16-level agreement
    np.testing.assert_allclose(np.asarray(jnp_o), np.asarray(pl_o),
                               atol=2e-2, rtol=2e-2)


# -- bank timing -------------------------------------------------------------

@forall(n_cases=40,
        arrived=int32_grid((6, 256), 0, 2), is_write=int32_grid((6, 256), 0, 2),
        row=int32_grid((6, 256), 0, 8), open_e=int32_grid((6, 256), -1, 8),
        nrd=int32_grid((6, 256), 0, 100), nwr=int32_grid((6, 256), 0, 100),
        nact=int32_grid((6, 256), 0, 100), npre=int32_grid((6, 256), 0, 100),
        faw=int32_grid((6, 256), 0, 2), hitp=int32_grid((6, 256), 0, 2),
        arrival=int32_grid((6, 256), 0, 1000),
        scal=int32_grid((6, 6), 0, 100), cap=integers(0, 4))
def test_frfcfs_select_kernel_vs_reference(arrived, is_write, row, open_e,
                                           nrd, nwr, nact, npre, faw, hitp,
                                           arrival, scal, cap):
    from repro.kernels.bank_timing import (frfcfs_select, pack_scalars,
                                           scalars_tuple, select_reference)
    args = [jnp.asarray(a) for a in
            (arrived, is_write, row, open_e, nrd, nwr, nact, npre, faw,
             hitp, arrival)]
    ch = pack_scalars(jnp.int32(50), *(jnp.asarray(scal[:, i])
                                       for i in range(1, 6)))
    sel_k, cmd_k = frfcfs_select(*args, ch, row_hit_cap=cap)
    sel_r, cmd_r = select_reference(*args, scalars_tuple(ch),
                                    row_hit_cap=cap)
    assert (np.asarray(cmd_k) == np.asarray(cmd_r)).all()
    # when a command is selected, the slot must match too
    live = np.asarray(cmd_r) != 0
    assert (np.asarray(sel_k)[live] == np.asarray(sel_r)[live]).all()


# -- addr decode -------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 100, 1024, 4097])
def test_addr_decode_kernel_shapes(n):
    from repro.kernels.addr_decode import decode_skylake, decode_reference
    lines = jnp.asarray(RNG.integers(0, 2 ** 32, n, dtype=np.uint32))
    d = decode_skylake(lines)
    r = decode_reference(lines)
    for f in d._fields:
        assert getattr(d, f).shape == (n,)
        assert (np.asarray(getattr(d, f))
                == np.asarray(getattr(r, f))).all(), f
