"""Device preset registry: geometry, timing legality, per-preset runs."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PRESETS, get_preset, get_stage, platform_for, stage_for
from repro.core import addrmap, dram, reference
from repro.core.dram import SchedulerPolicy
from repro.core.stages import STAGES
from repro.core.timing import DramParams


def test_ddr4_preset_is_the_default_params():
    """PR-1 results depend on this: the DDR4 preset IS DramParams()."""
    assert get_preset("ddr4_2666") == DramParams()


def test_unknown_preset_raises_with_catalog():
    with pytest.raises(ValueError, match="unknown device preset"):
        get_preset("gddr6")
    with pytest.raises(ValueError, match="ddr5_4800"):
        get_preset("nope")


@pytest.mark.parametrize("name", list(PRESETS))
def test_preset_geometry_is_consistent(name):
    d = get_preset(name)
    assert d.banks_per_rank % d.bank_groups == 0
    assert d.banks_per_group >= 1
    assert d.lines_per_row >= 16
    assert d.tRC == d.tRAS + d.tRP
    # data rate consistent with the clock (DDR: 2 transfers/cycle),
    # within the integer-picosecond rounding documented in presets.py
    assert d.mt_per_s * d.dram_ps_per_clk == pytest.approx(2e6, rel=0.005)


def test_preset_peak_bandwidths():
    assert get_preset("ddr4_2666").peak_gbs == pytest.approx(128.0, rel=0.01)
    assert get_preset("ddr5_4800").peak_gbs == pytest.approx(230.4, rel=0.01)
    assert get_preset("hbm2e").peak_gbs == pytest.approx(409.6, rel=0.01)


def test_reference_family_per_preset():
    for name in PRESETS:
        bw, lat = reference.curve(1.0, n=16, preset=name)
        assert lat[0] == pytest.approx(reference.unloaded_ns(name), rel=0.01)
        assert (np.diff(lat) >= -1e-9).all()          # monotone knee
    # HBM trades latency for parallelism: higher unloaded, more headroom
    assert (reference.unloaded_ns("hbm2e")
            > reference.unloaded_ns("ddr4_2666"))
    assert (reference.max_bandwidth_gbs(1.0, "hbm2e")
            > reference.max_bandwidth_gbs(1.0, "ddr5_4800")
            > reference.max_bandwidth_gbs(1.0, "ddr4_2666"))


@pytest.mark.parametrize("name", list(PRESETS))
@pytest.mark.parametrize("mapping", ["simple", "skylake_xor"])
def test_addrmap_fields_in_range_all_presets(name, mapping):
    d = get_preset(name)
    lines = jnp.arange(50000, dtype=jnp.uint32) * 977
    dec = addrmap.decode(lines, mapping, dram=d)
    assert addrmap.check_fields(dec, d)
    fb = np.asarray(dec.flat_bank_for(d))
    assert (fb >= 0).all() and (fb < d.banks_per_channel).all()
    # channel spread stays uniform-ish on every geometry
    counts = np.bincount(np.asarray(dec.channel), minlength=d.n_channels)
    assert counts.min() > 0.5 * counts.mean()


def test_skylake_xor_falls_back_generic_off_ddr4_geometry():
    lines = jnp.arange(4096, dtype=jnp.uint32)
    ddr4 = addrmap.decode(lines, "skylake_xor", dram=get_preset("ddr4_2666"))
    ddr4_none = addrmap.decode(lines, "skylake_xor")
    for f in ddr4._fields:
        assert (np.asarray(getattr(ddr4, f))
                == np.asarray(getattr(ddr4_none, f))).all()
    hbm = addrmap.decode(lines, "skylake_xor", dram=get_preset("hbm2e"))
    assert addrmap.check_fields(hbm, get_preset("hbm2e"))


def test_stage_for_and_get_stage_preset():
    cfg = get_stage("04-model-correct", preset="ddr5_4800")
    assert cfg.platform.dram == get_preset("ddr5_4800")
    assert cfg.platform.cpu == STAGES["04-model-correct"].platform.cpu
    # registry untouched; ddr4 request returns the registered config
    assert STAGES["04-model-correct"].platform.dram == DramParams()
    assert get_stage("04-model-correct", preset="ddr4_2666") is \
        STAGES["04-model-correct"]
    assert stage_for("04-model-correct", "hbm2e").platform.dram == \
        get_preset("hbm2e")
    assert platform_for("hbm2e").dram == get_preset("hbm2e")


# ------------------------------------------------ same-bank refresh (DDR5)

def _tiny_ddr5(**kw):
    """A small same-bank-refresh device for direct `dram.tick` driving."""
    base = dataclasses.asdict(get_preset("ddr5_4800"))
    base.update(n_channels=1, ranks_per_channel=1, **kw)
    return DramParams(**base)


def test_same_bank_refresh_blocks_only_target_bank():
    d = _tiny_ddr5(tREFI=5)
    pol = SchedulerPolicy(queue_depth=8)
    q = dram.init_queue(d, pol)
    b = dram.init_banks(d)
    # open rows everywhere; refresh will fire at t >= tREFI on bank 0
    b = b._replace(open_row=b.open_row * 0 + 7,
                   next_ref=b.next_ref * 0 + d.tREFI)
    for t in range(d.tREFI + 1):
        q, b, _ = dram.tick(q, b, jnp.int32(t), dram=d, policy=pol,
                            tick2cpu_num=d.dram_ps_per_clk, tick2cpu_den=1,
                            cpu_ps_per_clk=476)
    open_row = np.asarray(b.open_row)[0]
    # REFsb: bank 0 closed + blocked for tRFCsb, every other bank intact
    assert open_row[0] == -1
    assert (open_row[1:] == 7).all()
    assert int(np.asarray(b.next_act)[0, 0]) >= d.tREFI + d.tRFC
    assert (np.asarray(b.next_act)[0, 1:] < d.tREFI).all()
    # the rotation advanced to bank 1
    assert int(np.asarray(b.ref_slot)[0, 0]) == 1


def test_all_bank_refresh_unchanged_on_ddr4():
    d = DramParams()
    pol = SchedulerPolicy(queue_depth=8)
    q = dram.init_queue(d, pol)
    b0 = dram.init_banks(d)
    b = b0._replace(open_row=b0.open_row * 0 + 3,
                    next_ref=b0.next_ref * 0 + 2)
    for t in range(3):
        q, b, _ = dram.tick(q, b, jnp.int32(t), dram=d, policy=pol,
                            tick2cpu_num=750, tick2cpu_den=1,
                            cpu_ps_per_clk=476)
    # rank 0 of every channel fully closed (all-bank refresh)
    assert (np.asarray(b.open_row)[:, :d.banks_per_rank] == -1).all()
    assert (np.asarray(b.ref_slot) == 0).all()


# ------------------------------------------------------- end-to-end smoke

def test_replay_grid_covers_preset_stage_app():
    """One invocation -> the full preset x stage x app scenario grid."""
    import numpy as np
    from repro.traces import make_suite, replay_grid, stack_traces

    _, traces = make_suite(n=256, names=("stream", "pointer_chase"))
    grid = replay_grid(("ddr4_2666", "hbm2e"), ("03-ps-clock",),
                       stack_traces(traces), windows=8, warmup=2)
    assert set(grid) == {"ddr4_2666", "hbm2e"}
    for preset, stages in grid.items():
        out = stages["03-ps-clock"]
        assert out["runtime_ms"].shape == (2,)
        assert np.isfinite(out["runtime_ms"]).all()
        assert (out["n_rd"] > 0).all(), preset


def test_run_point_on_ddr5_preset():
    import jax
    from repro.core import run_point

    cfg = get_stage("03-ps-clock", preset="ddr5_4800", windows=12, warmup=4)
    out = jax.jit(lambda p, w: run_point(cfg, p, w))(
        jnp.int32(24), jnp.int32(0))
    out = {k: float(v) for k, v in out.items()}
    assert out["n_rd"] > 0
    assert out["sim_bw_gbs"] > 10.0
    # picosecond clocking holds on the new device's clock ratio too
    assert out["if_bw_gbs"] / out["sim_bw_gbs"] == pytest.approx(1.0,
                                                                 rel=1e-3)
