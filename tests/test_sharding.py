"""Logical-axis resolution: divisibility fallback + dedup rules."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.axes import (multi_pod_rules, resolve, shard,
                                 sharding_rules, single_pod_rules)


def mk_mesh():
    # degenerate single-device mesh with the production axis names;
    # sizes come from the rules-divisibility test via fake sizes below
    return jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])


class FakeMesh:
    """Shape-only stand-in so resolution logic is testable without
    512 devices."""

    def __init__(self, shape, names):
        import numpy as np
        self.axis_names = names
        self.devices = np.empty(shape)


def with_rules(fn, multi=False):
    mesh = (FakeMesh((2, 16, 16), ("pod", "data", "model")) if multi
            else FakeMesh((16, 16), ("data", "model")))
    rules = multi_pod_rules() if multi else single_pod_rules()
    with sharding_rules(mesh, rules):
        return fn()


def test_divisible_dims_shard():
    spec = with_rules(lambda: resolve(
        ("fsdp", "heads", None), (8192, 64, 128)))
    assert spec == P("data", "model")


def test_indivisible_heads_replicate():
    # whisper: 20 heads on a 16-way model axis -> replicated
    spec = with_rules(lambda: resolve(
        ("fsdp", "heads", None), (1280, 20, 64)))
    assert spec == P("data")


def test_dedup_first_dim_wins():
    # experts and mlp both map to 'model': experts (divisible) wins,
    # mlp is dropped
    spec = with_rules(lambda: resolve(
        ("experts", "fsdp", "mlp"), (128, 7168, 4864)))
    assert spec == P("model", "data")


def test_grok_fallback_ep_to_tp():
    # 8 experts on a 16-way axis: experts dropped, mlp picks up model
    spec = with_rules(lambda: resolve(
        ("experts", "fsdp", "mlp"), (8, 6144, 32768)))
    assert spec == P(None, "data", "model")


def test_kv_seq_flash_decoding_rules():
    # batched decode: batch takes data, kv_seq picks up model
    spec = with_rules(lambda: resolve(
        ("batch", "kv_seq", "kv_heads", None), (128, 32768, 8, 128)))
    assert spec == P("data", "model")
    # batch=1 long-context: batch drops, kv_seq takes BOTH axes
    spec = with_rules(lambda: resolve(
        ("batch", "kv_seq", "kv_heads", None), (1, 524288, 8, 128)))
    assert spec == P(None, ("data", "model"))


def test_multi_pod_batch_spans_pod_and_data():
    spec = with_rules(lambda: resolve(
        ("batch", None, None), (256, 4096, 1024)), multi=True)
    assert spec == P(("pod", "data"))


def test_no_rules_is_noop():
    assert resolve(("batch", None)) == P()
    import jax.numpy as jnp
    x = jnp.zeros((4, 4))
    assert shard(x, "batch", None) is x


def test_trailing_nones_trimmed():
    spec = with_rules(lambda: resolve((None, "heads", None), (1, 64, 64)))
    assert spec == P(None, "model")
