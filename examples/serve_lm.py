"""Serving example: continuous batching over a slot pool.

Loads (or trains briefly) a small model and pushes a stream of
requests through the Engine — demonstrating slot admission, per-slot
KV-cache isolation, and the decode step that the dry-run's decode
cells lower at production scale.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch zamba2-2.7b]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry as cfgs
from repro.models.common import ModelConfig
from repro.models.registry import get_model
from repro.serve.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(cfgs.ARCHS), default=None,
                    help="serve the smoke variant of an assigned arch")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = (cfgs.get_smoke(args.arch) if args.arch else
           ModelConfig(name="serve-demo", n_layers=2, d_model=128,
                       n_heads=8, n_kv_heads=2, d_ff=256, vocab=512,
                       dtype=jnp.float32))
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    ctx = None
    if api.needs_ctx:
        ctx = jnp.asarray(np.random.default_rng(0).standard_normal(
            (args.slots, cfg.n_ctx_tokens, cfg.d_model)), jnp.float32)

    eng = Engine(api, params, n_slots=args.slots, max_seq=128, ctx=ctx)
    rng = np.random.default_rng(1)
    for i in range(args.requests):
        plen = int(rng.integers(2, 8))
        eng.submit(Request(
            rid=i, prompt=list(rng.integers(1, cfg.vocab, plen)),
            max_new=int(rng.integers(4, 12))))

    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in done)
    print(f"[serve_lm] {cfg.name}: {len(done)}/{args.requests} requests, "
          f"{toks} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s across {args.slots} slots)")
    for r in done[:4]:
        print(f"  rid={r.rid} prompt={r.prompt} -> {r.out}")


if __name__ == "__main__":
    main()
