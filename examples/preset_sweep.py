"""Fleet-scale scenario grid: preset x stage x application in one call.

Replays the DAMOV-style application suite on every memory-device preset
(DDR4-2666, DDR5-4800, HBM2e) across two simulation stages — the
broken baseline and the corrected interface — and prints, per cell,
the predicted runtimes plus the MAPE against that preset's own
real-system anchors.

Each (preset, stage) cell is one compiled program; the application
axis is sharded across every available device (`repro.core.shard`),
falling back to plain `jax.vmap` on a single CPU.  To see actual
multi-device sharding on a CPU-only machine:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        PYTHONPATH=src python examples/preset_sweep.py

Run:  PYTHONPATH=src python examples/preset_sweep.py
"""
import jax

from repro.core import PRESET_ORDER, get_preset
from repro.core.shard import device_count
from repro.traces import anchor_suite_ms, make_suite, mape, replay_grid, \
    stack_traces

STAGES = ("01-baseline", "04-model-correct")


def main():
    names, traces = make_suite(n=1024)
    batch = stack_traces(traces)
    print(f"devices: {device_count()} ({jax.devices()[0].platform}); "
          f"app axis sharded across all of them\n")

    grid = replay_grid(PRESET_ORDER, STAGES, batch, windows=24, warmup=8)

    for preset, stages in grid.items():
        anchors = anchor_suite_ms(traces, preset)
        peak = get_preset(preset).peak_gbs
        print(f"== {preset}  (theoretical peak {peak:.0f} GB/s)")
        for stage, out in stages.items():
            err = mape(out["runtime_ms"], anchors)
            print(f"  [{stage}]  runtime MAPE vs {preset} anchors: "
                  f"{err:5.1f}%")
            for i, nm in enumerate(names):
                print(f"     {nm:14s} {out['runtime_ms'][i]:8.4f} ms "
                      f"(anchor {anchors[i]:8.4f} ms, "
                      f"sim {out['sim_bw_gbs'][i]:6.1f} GB/s)")
        print()
    print("-> the baseline's decoupled app view replays latency-bound"
          "\n   kernels far too fast on every device generation; the"
          "\n   corrected interface recouples them (the paper's claim,"
          "\n   re-validated per preset).")


if __name__ == "__main__":
    main()
