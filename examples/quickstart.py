"""Quickstart: both halves of the framework in two minutes.

1. The paper core: Mess-characterize the integrated CPU+memory
   simulator at the baseline and corrected stages — watch the
   application view decouple (bug) and recouple (fix).
2. The LM substrate: train a small GQA transformer on synthetic data
   for 60 steps and greedy-decode from it.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import get_stage, sweep
from repro.data.synthetic import DataConfig, Stream
from repro.models.common import ModelConfig
from repro.models.registry import get_model
from repro.serve.engine import Engine, Request
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def part1_simulator():
    print("=" * 64)
    print("1) Memory-system simulation: three views, two stages")
    print("=" * 64)
    # stage + device preset come from the registries (repro.core.stages
    # / repro.core.presets) — never hand-build DramParams.  Swap the
    # preset to "ddr5_4800" / "hbm2e" to rerun on another device.
    preset = "ddr4_2666"
    for stage in ("01-baseline", "04-model-correct"):
        res = sweep(get_stage(stage, preset=preset, windows=32, warmup=12),
                    paces=(2, 24, 56), write_mixes=(0,))
        print(f"\n[{stage} @ {preset}] bandwidth sweep (100% reads):")
        print("   used GB/s | sim-view ns | iface ns | APP ns")
        for j in range(len(res.paces)):
            print(f"   {res.app_bw[0, j]:9.1f} | {res.sim_lat[0, j]:11.1f}"
                  f" | {res.if_lat[0, j]:8.1f} | {res.app_lat[0, j]:6.1f}")
    print("\n-> baseline app view is stuck at ~24 ns (the decoupling "
          "bug);\n   the corrected stage tracks the memory system.\n"
          "   (examples/preset_sweep.py runs the preset x stage x app "
          "grid.)")


def part2_train_and_serve():
    print("\n" + "=" * 64)
    print("2) LM substrate: train a tiny GQA transformer + serve it")
    print("=" * 64)
    cfg = ModelConfig(name="quickstart", n_layers=2, d_model=128,
                      n_heads=8, n_kv_heads=2, d_ff=256, vocab=256,
                      dtype=jnp.float32)
    api = get_model(cfg)
    data = DataConfig(vocab=256, seq_len=64, global_batch=8,
                      structure=0.9)
    trainer = Trainer(api, AdamWConfig(lr=1e-3, warmup_steps=10),
                      TrainerConfig(total_steps=60, ckpt_every=0,
                                    log_every=20))
    res = trainer.fit(Stream(data))
    print(f"loss: {res['losses'][0]:.3f} -> {res['losses'][-1]:.3f}")

    eng = Engine(api, trainer.params, n_slots=2, max_seq=64)
    eng.submit(Request(rid=0, prompt=[5, 17, 23], max_new=8))
    eng.submit(Request(rid=1, prompt=[9, 2], max_new=8))
    for r in eng.run():
        print(f"request {r.rid}: prompt={r.prompt} -> {r.out}")


if __name__ == "__main__":
    part1_simulator()
    part2_train_and_serve()
