"""Multiprogrammed trace replay: per-core mixes + the two-socket frontend.

Two demos of the per-core replay generalization:

1. A mixed workload — a streaming kernel and a pointer chase on
   *disjoint core sets* of one socket.  Each core prices its own
   stream with its own cursor, so the latency-bound app's in-mix
   runtime shows the queueing delay its streaming neighbour creates —
   contention the solo replay (and the decoupled baseline) cannot see.
2. The second traffic socket: one Mess operating point on HBM2e with
   ``n_sockets=2`` (47 traffic cores), driving the device past the
   ~200 GB/s single-socket frontend ceiling.

    PYTHONPATH=src python examples/mix_replay.py

Runs CI-speed (small traces, few windows); see
benchmarks/app_validation.py --mix for the full validation.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import get_stage, run_point
from repro.traces import assign_traces, mix_stats, replay_mix
from repro.traces.kernels import pointer_chase, stream


def main():
    # ---- 1. a two-app mix on disjoint core sets ------------------------
    apps = {"stream": stream(n=2048), "pointer_chase": pointer_chase(n=128)}
    names = list(apps)
    # stream on cores 0-10, chase on 11-22; core 23 is the latency probe
    assignment = [0] * 11 + [1] * 12 + [-1]
    mix = assign_traces(list(apps.values()), assignment)
    print("mix:", mix_stats(mix))

    cfg = get_stage("04-model-correct", windows=48, warmup=8)
    out = replay_mix(cfg, mix)
    print(f"platform during mix: {out['sim_bw_gbs']:.1f} GB/s, "
          f"sim latency {out['sim_lat_ns']:.0f} ns")
    for a, nm in enumerate(names):
        print(f"  {nm:14s} cores={assignment.count(a):2d} "
              f"in-mix runtime {out['app_runtime_ms'][a]:.4f} ms "
              f"(done={bool(out['app_done'][a])})")

    # the same latency-bound app with the rest of the socket idle:
    solo = replay_mix(cfg, assign_traces(
        [apps["pointer_chase"]], [-1] * 11 + [0] * 12 + [-1]))
    slow = (out["app_runtime_ms"][1] / solo["app_runtime_ms"][0] - 1) * 100
    print(f"pointer_chase slowdown from the streaming neighbour: "
          f"{slow:+.0f}% — interface contention the solo replay never sees")

    # ---- 2. the second traffic socket on HBM2e -------------------------
    for n_sockets in (1, 2):
        # a max-pace saturation probe: pin the dense weave oracle (the
        # event engine's budget binds past the knee and would flag it)
        cfg = get_stage("04-model-correct", preset="hbm2e", windows=16,
                        warmup=4, n_sockets=n_sockets, weave="dense")
        v = run_point(cfg, jnp.int32(64), jnp.int32(0))
        print(f"hbm2e @ pace 64, {n_sockets} socket(s): "
              f"{float(v['sim_bw_gbs']):.0f} GB/s served "
              f"({24 * n_sockets - 1} traffic cores)")
    print("the second socket lifts the frontend ceiling past 300 GB/s")


if __name__ == "__main__":
    main()
