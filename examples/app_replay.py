"""Replay an application trace through the simulated platform.

Demonstrates the third perspective: instead of sweeping the synthetic
Mess pace generator, drive the platform with a real access pattern and
read out what each view claims the application experienced.

    PYTHONPATH=src python examples/app_replay.py

Expected output: the baseline stage predicts nearly identical runtimes
for a streaming and a pointer-chasing kernel (the decoupling bug — the
bound phase never sees memory latency), while the corrected stage
separates them by the ~4x the real machine shows.
"""
from __future__ import annotations

from repro.core import get_stage
from repro.traces import (anchor_runtime_ms, make_suite, replay_suite,
                          stack_traces, trace_stats)

APPS = ("stream", "pointer_chase")


def main():
    names, traces = make_suite(n=2048, names=APPS)
    batch = stack_traces(traces)

    for nm, tr in zip(names, traces):
        st = trace_stats(tr)
        print(f"{nm:14s} {st['accesses']} accesses, "
              f"{st['write_frac']:.0%} writes, {st['dep_frac']:.0%} "
              f"dependent, {st['footprint_mb']:.0f} MB/core")

    ratios = {}
    for stage in ("01-baseline", "04-model-correct"):
        cfg = get_stage(stage, windows=32, warmup=8)
        out = replay_suite(cfg, batch)
        ratios[stage] = out["runtime_ms"][1] / out["runtime_ms"][0]
        print(f"\n== {stage} ==")
        for i, nm in enumerate(names):
            anchor = anchor_runtime_ms(traces[i])
            print(f"  {nm:14s} runtime {out['runtime_ms'][i]:.3f} ms "
                  f"(real machine ~{anchor:.3f})  "
                  f"views sim/if/app latency = "
                  f"{out['sim_lat_ns'][i]:.0f}/{out['if_lat_ns'][i]:.0f}/"
                  f"{out['app_lat_ns'][i]:.0f} ns")
    real = anchor_runtime_ms(traces[1]) / anchor_runtime_ms(traces[0])
    print(f"\npointer_chase/stream runtime ratio: baseline "
          f"{ratios['01-baseline']:.1f}x, corrected "
          f"{ratios['04-model-correct']:.1f}x, real machine {real:.1f}x "
          "— the decoupled baseline hides most of the latency-bound "
          "slowdown")


if __name__ == "__main__":
    main()
