"""End-to-end training driver: ~100M-param LM for a few hundred steps.

The default profile is sized for this CPU container (a ~12M model,
200 steps, a few minutes).  ``--profile 100m`` selects the full
~100M-parameter model x 300 steps the assignment describes — the same
code path, bigger numbers.  Checkpointing, preemption handling and
straggler detection are live in both profiles (SIGTERM the process and
restart it with the same args: it resumes).

Run:  PYTHONPATH=src python examples/train_lm.py [--profile 100m]
      [--arch <assigned-arch>]    # train a smoke variant of any arch
"""
import argparse

import jax.numpy as jnp

from repro.configs import registry as cfgs
from repro.data.synthetic import DataConfig, Stream
from repro.models.common import ModelConfig
from repro.models.registry import count_params, get_model
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig

PROFILES = {
    "small": dict(
        cfg=ModelConfig(name="lm-12m", n_layers=4, d_model=256,
                        n_heads=8, n_kv_heads=4, d_ff=1024, vocab=8192,
                        dtype=jnp.float32),
        steps=200, batch=8, seq=256),
    "100m": dict(
        cfg=ModelConfig(name="lm-100m", n_layers=12, d_model=768,
                        n_heads=12, n_kv_heads=4, d_ff=2048, vocab=32000,
                        dtype=jnp.float32),
        steps=300, batch=32, seq=512),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", choices=PROFILES, default="small")
    ap.add_argument("--arch", choices=list(cfgs.ARCHS), default=None,
                    help="train the smoke variant of an assigned arch")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    prof = PROFILES[args.profile]
    cfg = cfgs.get_smoke(args.arch) if args.arch else prof["cfg"]
    api = get_model(cfg)
    steps = args.steps or prof["steps"]
    vocab = cfg.vocab
    data = DataConfig(vocab=vocab, seq_len=prof["seq"],
                      global_batch=prof["batch"], structure=0.85)

    trainer = Trainer(
        api,
        AdamWConfig(lr=3e-4, warmup_steps=max(10, steps // 20),
                    total_steps=steps),
        TrainerConfig(total_steps=steps, ckpt_every=max(50, steps // 4),
                      ckpt_dir=args.ckpt_dir, accum=2, log_every=10,
                      compress_grads=args.compress_grads))
    n = count_params(trainer.params)
    print(f"[train_lm] {cfg.name}: {n / 1e6:.1f}M params, "
          f"{steps} steps, batch {prof['batch']} x seq {prof['seq']}")
    if trainer.maybe_resume():
        print(f"[train_lm] resuming at step {trainer.step_idx}")
    stream = Stream(data)
    stream.seek(trainer.step_idx)
    res = trainer.fit(stream)
    print(f"[train_lm] done: step {res['final_step']}, "
          f"loss {res['losses'][0]:.3f} -> {res['losses'][-1]:.3f}")


if __name__ == "__main__":
    main()
