"""The paper's experiment end-to-end: stage progression vs ground truth.

Runs every artifact stage (00-10) through the Mess characterization and
prints the key validation metrics of each figure next to the measured
Intel Skylake reference — the exact validation loop the paper argues
for: judge simulators at the APPLICATION view against real-HW curves.

Run:  PYTHONPATH=src python examples/mess_validation.py [--full]
"""
import argparse

import numpy as np

from repro.core import STAGE_ORDER, get_stage, sweep
from repro.core import reference


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    kw = {} if args.full else dict(windows=48, warmup=16)
    paces = (1, 4, 12, 24, 48, 64)

    print(f"{'stage':18s} {'unloaded':>9s} {'sat-bw':>7s} {'sat-lat':>8s} "
          f"{'if/sim bw':>9s} {'app flat?':>9s}")
    print(f"{'actual Skylake':18s} {reference.UNLOADED_NS:9.1f} "
          f"{reference.max_bandwidth_gbs(1.0):7.1f} "
          f"{reference.latency_ns(119, 1.0):8.1f} {'1.00':>9s} {'no':>9s}")
    print("-" * 66)
    for stage in STAGE_ORDER:
        res = sweep(get_stage(stage, **kw), paces=paces, write_mixes=(0,))
        ratio = float((res.if_bw / np.maximum(res.sim_bw, 1e-9)).mean())
        flat = "YES(bug)" if np.ptp(res.app_lat[0]) < 2.0 else "no"
        print(f"{stage:18s} {res.app_lat[0, 0]:9.1f} "
              f"{res.app_bw[0].max():7.1f} {res.app_lat[0].max():8.1f} "
              f"{ratio:9.2f} {flat:>9s}")
    print("\napp-view columns; the paper's narrative reads top to "
          "bottom:\n 01: flat 24 ns + inflated bw -> 03: bw fixed -> "
          "04: latency recoupled -> 05-07: gradient/NOC/prefetch -> "
          "10: unloaded matches actual.")


if __name__ == "__main__":
    main()
