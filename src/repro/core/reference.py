"""Real-system memory curves per device preset — the ground truth.

The paper validates every simulation stage against Mess measurements of
the actual server (Fig. 2a).  The Mess methodology is defined per
memory technology as a *family* of bandwidth-latency curves (one per
read/write mix), and "Cleaning up the Mess" shows fidelity does not
transfer across device generations — so this module carries one curve
family per `repro.core.presets` device:

* ``ddr4_2666`` — the paper's measured Skylake (MareNostrum 4) curves.
  Anchor points from the paper's text: 89 ns unloaded load-to-use,
  saturation between 100 GB/s (write-heavy) and 120 GB/s (100% read),
  saturated latency 240 ns (100% read) to 390 ns (50% read).
* ``ddr5_4800`` — a DDR5-4800 server socket (Sapphire-Rapids-class,
  6 DIMMs = 12 sub-channels): ~92 ns unloaded, saturation ~210 GB/s
  (100% read) to ~170 GB/s (50% read).
* ``hbm2e`` — one HBM2e stack: ~108 ns unloaded (HBM trades latency
  for parallelism), device saturation ~330 GB/s at 100% read — the
  ~80%-of-pin-peak efficiency measured HBM2e parts reach (409.6 GB/s
  theoretical for this stack).  A single 24-core socket offers at
  most ~198 GB/s and only exercises the low-utilization region; the
  two-socket frontend (``StageConfig.n_sockets = 2``, 47 traffic
  cores) drives the simulated device past 300 GB/s into the knee,
  which is what these saturation anchors were re-calibrated against
  (docs/VALIDATION.md has the methodology).

All anchor tables are analytic references in the role of the paper's
real-hardware column: unloaded latency, per-mix saturation bandwidth
and saturated latency, with the usual closed-system queueing knee
(latency growth ~ u^2/(1-u)) between them — the measured shape of
Mess curves on all three technologies.

Units: bandwidth GB/s, latency ns (load-to-use, application level).
"""
from __future__ import annotations

import numpy as np

#: per-preset (unloaded latency ns,
#:             {read_fraction: (saturation GB/s, saturated latency ns)})
_FAMILIES: dict[str, tuple[float, dict[float, tuple[float, float]]]] = {
    "ddr4_2666": (89.0, {
        1.00: (120.0, 240.0),
        0.87: (115.0, 280.0),
        0.75: (110.0, 320.0),
        0.62: (105.0, 355.0),
        0.50: (100.0, 390.0),
    }),
    "ddr5_4800": (92.0, {
        1.00: (210.0, 175.0),
        0.87: (200.0, 200.0),
        0.75: (190.0, 225.0),
        0.62: (180.0, 250.0),
        0.50: (170.0, 275.0),
    }),
    "hbm2e": (108.0, {
        1.00: (330.0, 160.0),
        0.87: (322.0, 175.0),
        0.75: (314.0, 190.0),
        0.62: (306.0, 205.0),
        0.50: (298.0, 220.0),
    }),
}

# Backward-compatible DDR4 module-level aliases (paper platform).
UNLOADED_NS = _FAMILIES["ddr4_2666"][0]
_ANCHORS = _FAMILIES["ddr4_2666"][1]
READ_FRACTIONS = tuple(sorted(_ANCHORS, reverse=True))


def _family(preset: str):
    try:
        return _FAMILIES[preset]
    except KeyError:
        raise ValueError(f"unknown reference preset {preset!r}; "
                         f"one of {list(_FAMILIES)}") from None


def unloaded_ns(preset: str = "ddr4_2666") -> float:
    """Unloaded load-to-use latency (ns) of the preset's real system."""
    return _family(preset)[0]


def _interp_anchor(read_frac: float,
                   preset: str = "ddr4_2666") -> tuple[float, float]:
    anchors = _family(preset)[1]
    fracs = np.array(sorted(anchors))
    bws = np.array([anchors[f][0] for f in fracs])
    lats = np.array([anchors[f][1] for f in fracs])
    return (float(np.interp(read_frac, fracs, bws)),
            float(np.interp(read_frac, fracs, lats)))


def latency_ns(bw_gbs, read_frac: float = 1.0, preset: str = "ddr4_2666"):
    """Real-system load-to-use latency (ns) at ``bw_gbs`` used bandwidth.

    Args:
        bw_gbs: used bandwidth in GB/s (vectorized).
        read_frac: read fraction of the traffic mix, in [0.5, 1.0].
        preset: device preset name (`repro.core.presets`).
    Returns:
        Latency in ns.  Saturates at the per-mix maximum latency;
        bandwidth beyond the per-mix saturation point is clamped (the
        real system cannot exceed it).
    """
    unloaded = _family(preset)[0]
    bw_sat, lat_sat = _interp_anchor(read_frac, preset)
    bw = np.minimum(np.asarray(bw_gbs, dtype=np.float64), bw_sat * 0.999)
    u = bw / bw_sat
    # Queueing knee calibrated so lat(u=0)=unloaded and lat(u->1)=lat_sat.
    # lat = unloaded + k * u^2/(1-u), with a cap at lat_sat.
    k = (lat_sat - unloaded) * 0.08
    lat = unloaded + k * (u ** 2) / np.maximum(1.0 - u, 0.02)
    return np.minimum(lat, lat_sat)


def max_bandwidth_gbs(read_frac: float = 1.0,
                      preset: str = "ddr4_2666") -> float:
    """Per-mix saturation bandwidth (GB/s) of the preset's real system."""
    return _interp_anchor(read_frac, preset)[0]


def curve(read_frac: float = 1.0, n: int = 64, preset: str = "ddr4_2666"):
    """(bandwidth GB/s, latency ns) arrays for one measured Mess curve."""
    bw_sat, _ = _interp_anchor(read_frac, preset)
    bw = np.linspace(0.0, bw_sat, n)
    return bw, latency_ns(bw, read_frac, preset)
