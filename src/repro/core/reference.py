"""Measured Intel Skylake (MareNostrum 4) memory curves — the ground truth.

The paper validates every simulation stage against Mess measurements of
the actual server (Fig. 2a).  We encode those measured curves as an
analytic reference: for each read/write mix, latency as a function of
used bandwidth.  Anchor points are taken from the paper's text:

  * unloaded load-to-use latency: 89 ns,
  * saturation between 100 GB/s (write-heavy) and 120 GB/s (100% read),
  * saturated latency between 240 ns (100% read) and 390 ns (50% read),
  * a clear light-to-dark gradient from 100%-read to 50%-read curves.

The shape between the anchors follows the usual closed-system
bandwidth-latency knee (queueing-delay growth ~ u/(1-u)); Mess curves of
Skylake-class DDR4 parts have exactly this profile.
"""
from __future__ import annotations

import numpy as np

UNLOADED_NS = 89.0
#: (read_fraction, saturation bandwidth GB/s, saturated latency ns)
_ANCHORS = {
    1.00: (120.0, 240.0),
    0.87: (115.0, 280.0),
    0.75: (110.0, 320.0),
    0.62: (105.0, 355.0),
    0.50: (100.0, 390.0),
}
READ_FRACTIONS = tuple(sorted(_ANCHORS, reverse=True))


def _interp_anchor(read_frac: float) -> tuple[float, float]:
    fracs = np.array(sorted(_ANCHORS))
    bws = np.array([_ANCHORS[f][0] for f in fracs])
    lats = np.array([_ANCHORS[f][1] for f in fracs])
    return (float(np.interp(read_frac, fracs, bws)),
            float(np.interp(read_frac, fracs, lats)))


def latency_ns(bw_gbs, read_frac: float = 1.0):
    """Measured-system load-to-use latency (ns) at `bw_gbs` used bandwidth.

    Vectorized over `bw_gbs`.  Saturates at the per-mix maximum latency;
    bandwidth beyond the per-mix saturation point is clamped (the real
    system cannot exceed it).
    """
    bw_sat, lat_sat = _interp_anchor(read_frac)
    bw = np.minimum(np.asarray(bw_gbs, dtype=np.float64), bw_sat * 0.999)
    u = bw / bw_sat
    # Queueing knee calibrated so lat(u=0)=UNLOADED and lat(u->1)=lat_sat.
    # lat = unloaded + k * u^2/(1-u), with a cap at lat_sat.
    k = (lat_sat - UNLOADED_NS) * 0.08
    lat = UNLOADED_NS + k * (u ** 2) / np.maximum(1.0 - u, 0.02)
    return np.minimum(lat, lat_sat)


def max_bandwidth_gbs(read_frac: float = 1.0) -> float:
    return _interp_anchor(read_frac)[0]


def curve(read_frac: float = 1.0, n: int = 64):
    """(bandwidth GB/s, latency ns) arrays for one measured Mess curve."""
    bw_sat, _ = _interp_anchor(read_frac)
    bw = np.linspace(0.0, bw_sat, n)
    return bw, latency_ns(bw, read_frac)
