"""Multi-device sharding of the platform's batch axes.

`run_frontend` is one compiled program per static stage configuration;
its batch axis — Mess pace points or stacked application traces — is
embarrassingly parallel.  `sharded_vmap` maps that axis across every
available accelerator with `jax.shard_map` (data-parallel, no
cross-shard communication) and degenerates to a plain `jax.vmap` on a
single device, so CPU CI and a TPU pod run the same call sites.

Because the mapped function is elementwise along the batch axis (no
collectives, no cross-batch reductions), the sharded result is
**bit-identical** to the single-device vmap result — asserted by
tests/test_sharding_sweeps.py.

Batch sizes that do not divide the device count are right-padded by
repeating the last element; `sharded_vmap` slices the padding off the
outputs, so callers never see it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec

try:                                    # jax >= 0.5 exposes it top-level
    from jax import shard_map as _shard_map       # type: ignore
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map

BATCH_AXIS = "batch"


def device_count() -> int:
    """Devices the sweep axes shard across (1 = plain vmap fallback)."""
    return jax.device_count()


def _pad_batch(tree, pad: int):
    return jax.tree_util.tree_map(
        lambda a: jnp.concatenate(
            [a, jnp.repeat(a[-1:], pad, axis=0)], axis=0),
        tree)


def _unpad_batch(tree, n: int):
    return jax.tree_util.tree_map(lambda a: a[:n], tree)


def sharded_vmap(fn, n_devices: int | None = None, donate: bool = False):
    """``vmap(fn)`` over the leading axis, sharded across devices.

    Args:
        fn: a function of one batched pytree argument; must be
            elementwise along the leading (batch) axis.
        n_devices: devices to shard over; defaults to all available.
            With one device this is exactly ``jax.vmap(fn)`` (no mesh,
            no padding) — the CPU fallback path.
        donate: donate the batched input buffers to the computation
            (``jax.jit(..., donate_argnums=0)``): XLA may alias them
            into outputs/scratch instead of holding a live copy per
            point, cutting per-point device copies and peak memory on
            large sweep batches.  The caller's input arrays are
            **consumed** — only pass ``True`` for buffers that are
            rebuilt per call (see `repro.core.mess.sweep`) or
            explicitly handed over (`repro.traces.replay`'s
            ``donate=`` entry points).
    Returns:
        A jitted function ``batched(tree) -> tree_out`` whose leading
        output axis matches the input batch length.  Results are
        bit-identical to the single-device vmap path.
    """
    nd = n_devices or device_count()
    if nd > device_count():
        raise ValueError(f"n_devices={nd} exceeds the "
                         f"{device_count()} available devices")
    dn = (0,) if donate else ()
    if nd <= 1:
        return jax.jit(jax.vmap(fn), donate_argnums=dn)

    mesh = Mesh(jax.devices()[:nd], (BATCH_AXIS,))
    spec = PartitionSpec(BATCH_AXIS)
    mapped = _shard_map(jax.vmap(fn), mesh=mesh,
                        in_specs=spec, out_specs=spec)
    jitted = jax.jit(mapped, donate_argnums=dn)

    @functools.wraps(fn)
    def batched(tree):
        n = jax.tree_util.tree_leaves(tree)[0].shape[0]
        pad = (-n) % nd
        out = jitted(_pad_batch(tree, pad) if pad else tree)
        return _unpad_batch(out, n) if pad else out

    return batched
