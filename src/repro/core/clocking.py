"""Cross-simulator clocking — the paper's central interface correction.

Three selectable clock models reproduce the paper's progression:

* ``broken_noscale`` — the DAMOV release state (Sec. 3.2): the block
  responsible for cross-simulator clocking is disabled, so the DRAM
  simulator is ticked once per *CPU* cycle.  The CPU perceives memory
  running 1.575x too fast: interface bandwidth exceeds the theoretical
  maximum by ~40% (Fig. 2c/2d).

* ``damov_ceil`` — clock scaling enabled, but with DAMOV's integer
  ``freqRatio = ceil(cpuFreq/memFreq) = 2`` (Code Listing 1a).  The
  memory simulator is ticked every 2 CPU cycles, i.e. at 1.05 GHz
  instead of 1.333 GHz — ~25% bandwidth loss at the interface (Fig. 3).

* ``picosecond`` — the paper's corrected interface (Code Listing 1b):
  CPU time advances by 476 ps per cycle; while the DRAM picosecond time
  lags the CPU time, the DRAM simulator is ticked and its time advances
  by 750 ps.  The exact 1.575 ratio is preserved (Fig. 4).

In this JAX port the per-cycle while-loop is aggregated per simulation
window (1000 CPU cycles): each model provides the number of DRAM ticks
in a window, the mapping from CPU-cycle timestamps to DRAM ticks
(request hand-off), and the mapping from DRAM ticks back to CPU
picoseconds (response hand-off / interface view).  All three are exact
integer reformulations of the per-cycle loops they replace.
"""
from __future__ import annotations

import dataclasses

from repro.core.timing import PlatformParams, DEFAULT_PLATFORM

CLOCK_MODES = ("broken_noscale", "damov_ceil", "picosecond")


@dataclasses.dataclass(frozen=True)
class ClockModel:
    """Static description of one cross-simulator clocking scheme."""

    mode: str
    cpu_ps_per_clk: int                 # 476
    dram_ps_per_clk: int                # 750
    window_cycles: int                  # 1000
    ticks_per_window_static: int        # static scan length (upper bound)
    # tick -> CPU-perceived picoseconds:  cpu_ps = tick * num // den
    tick_to_cpu_ps_num: int
    tick_to_cpu_ps_den: int
    # cpu cycle -> DRAM tick:  tick = (cycle*c2t_num + c2t_round) // c2t_den
    c2t_num: int
    c2t_den: int
    c2t_round: int = 0
    #: static scan length of the event-horizon weave engine (steps per
    #: window); derived from bus occupancy by `make_clock` — see
    #: `event_budget`.  Always <= ticks_per_window_static.
    events_per_window_static: int = 0

    def window_start_tick(self, w):
        """First DRAM tick of window ``w`` (exact, integer)."""
        return self.cycle_to_tick(w * self.window_cycles)

    def window_end_tick(self, w):
        return self.cycle_to_tick((w + 1) * self.window_cycles)

    def cycle_to_tick(self, cycle):
        """DRAM tick at which a request issued at ``cycle`` is visible.

        Reformulates Listing 1b: the first tick whose dramPs has caught
        up with the request's cpuPs (ceil for the picosecond model,
        matching the ``while (cpuPs > dramPs)`` loop exactly).
        """
        return (cycle * self.c2t_num + self.c2t_round) // self.c2t_den

    def tick_to_cpu_ps(self, tick):
        """CPU-perceived picosecond timestamp of DRAM tick ``tick``.

        Under ``broken_noscale`` a DRAM tick *is* a CPU cycle (476 ps);
        under ``damov_ceil`` a DRAM tick spans freqRatio=2 CPU cycles
        (952 ps); under ``picosecond`` it is the true 750 ps.
        """
        return tick * self.tick_to_cpu_ps_num // self.tick_to_cpu_ps_den

    def tick_to_sim_ps(self, tick):
        """The memory simulator's own notion of time (always 750 ps)."""
        return tick * self.dram_ps_per_clk

    def window_cpu_ps(self, w):
        """CPU-clock picosecond timestamp of window ``w``'s start.

        The wall-clock axis of exported timelines (`repro.obs.export`):
        window boundaries are defined on the CPU clock, so every
        per-window telemetry series shares this axis regardless of the
        DRAM tick mapping.
        """
        return w * self.window_cycles * self.cpu_ps_per_clk

    def active_ticks_in_window(self, w):
        """Traced count of DRAM ticks belonging to window ``w``.

        At most ``ticks_per_window_static`` (the scan length); for the
        picosecond model the count alternates 635/636 with the exact
        carry of Listing 1b.
        """
        return self.window_end_tick(w) - self.window_start_tick(w)


def event_budget(ticks: int, dram) -> int:
    """Static event-scan length for one window of ``ticks`` DRAM ticks.

    The event-horizon weave engine evaluates `repro.core.dram.tick`
    only at ticks where eligibility can change, so its scan length is
    bounded by how many *commands* a window can physically carry, not
    by the tick count:

    * **CAS slots** — the data bus fits at most ``ticks // tBL``
      bursts per channel per window, and cross-channel CAS ticks
      coalesce (one evaluated tick serves every channel) because
      request arrivals are windowed bursts;
    * **refresh** — ``ranks * (ticks // tREFI + 1)`` deadlines (the
      staggered per-rank grid is shared by all channels);
    * **headroom** — ACT/PRE interleave, arrival bursts, and drain
      settles: ``max(32, ticks // 16)``.

    The budget is clamped to ``ticks`` (the event engine can never
    need more steps than the dense scan).  When offered traffic pushes
    past what the budget covers, the engine saturates *gracefully*:
    remaining events spill into the next window and the window is
    flagged (`WindowOut` diagnostics / ``weave_sat`` in the views) —
    never silently wrong.  `StageConfig.weave_events` overrides this
    derivation.
    """
    cas_slots = ticks // dram.tBL
    refresh = dram.ranks_per_channel * (ticks // max(dram.tREFI, 1) + 1)
    headroom = max(32, ticks // 16)
    return min(ticks, cas_slots + refresh + headroom)


def make_clock(mode: str,
               platform: PlatformParams = DEFAULT_PLATFORM) -> ClockModel:
    cpu = platform.cpu
    dram = platform.dram
    cp, dp, wc = cpu.cpu_ps_per_clk, dram.dram_ps_per_clk, cpu.window_cycles
    if mode == "broken_noscale":
        # one DRAM tick per CPU cycle; CPU sees ticks as its own cycles
        return ClockModel(mode, cp, dp, wc,
                          ticks_per_window_static=wc,
                          tick_to_cpu_ps_num=cp, tick_to_cpu_ps_den=1,
                          c2t_num=1, c2t_den=1,
                          events_per_window_static=event_budget(wc, dram))
    if mode == "damov_ceil":
        r = platform.freq_ratio_ceil            # ceil(2.1/1.333) = 2
        return ClockModel(mode, cp, dp, wc,
                          ticks_per_window_static=wc // r,
                          tick_to_cpu_ps_num=cp * r, tick_to_cpu_ps_den=1,
                          c2t_num=1, c2t_den=r,
                          events_per_window_static=event_budget(
                              wc // r, dram))
    if mode == "picosecond":
        # Listing 1b: dram ticks while dramPs < cpuPs.
        # tick(cycle) = floor(cycle*476 / 750); max ticks/window = 636.
        import math
        tmax = math.ceil(wc * cp / dp)
        return ClockModel(mode, cp, dp, wc,
                          ticks_per_window_static=tmax,
                          tick_to_cpu_ps_num=dp, tick_to_cpu_ps_den=1,
                          c2t_num=cp, c2t_den=dp, c2t_round=dp - 1,
                          events_per_window_static=event_budget(tmax, dram))
    raise ValueError(f"unknown clock mode {mode!r}; one of {CLOCK_MODES}")


def reference_listing_1b(n_cpu_cycles: int,
                         platform: PlatformParams = DEFAULT_PLATFORM):
    """Direct Python transliteration of the paper's Code Listing 1(b).

    Used by tests as the oracle for the aggregated ClockModel: returns
    the (cpuPs, dramPs, dramCycle) trajectory after each CPU cycle.
    """
    cpu_ps = 0
    dram_ps = 0
    dram_cycle = 0
    cpu_ps_per_clk = platform.cpu.cpu_ps_per_clk
    dram_ps_per_clk = platform.dram.dram_ps_per_clk
    traj = []
    for _ in range(n_cpu_cycles):
        cpu_ps += cpu_ps_per_clk              # line 1-2
        while cpu_ps > dram_ps:               # line 3
            dram_ps += dram_ps_per_clk        # line 4-6: tick()
            dram_cycle += 1
        traj.append((cpu_ps, dram_ps, dram_cycle))
    return traj
