"""Timing constants for the simulated platform.

The platform mirrors the paper's experimental environment (Table 1):
an Intel Skylake server (MareNostrum 4) with 24 cores @ 2.1 GHz and
6 channels of DDR4-2666, 2 ranks/DIMM, 16 banks/device.

All DRAM timings are expressed in *memory bus cycles* (tCK = 750 ps for
DDR4-2666).  CPU-side latencies are expressed in CPU cycles (476 ps at
2.1 GHz).  The paper's picosecond clocking (Listing 1b) uses exactly
these integer picosecond periods: 476 ps and 750 ps.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CpuParams:
    """ZSim-side CPU parameters (paper Table 1, left column)."""

    n_cores: int = 24
    freq_ghz: float = 2.1
    cpu_ps_per_clk: int = 476          # 1 / 2.1 GHz, as in the paper
    window_cycles: int = 1000          # ZSim bound/weave window length
    # Load-to-use path (CPU cycles) excluding the memory system.  The
    # sum is calibrated so the baseline application view reproduces the
    # paper's flat 24 ns (~50 cycles at 2.1 GHz).
    core_issue_cycles: int = 4         # AGU + LSQ + ROB path
    l1_lookup_cycles: int = 4          # private 32 KB L1-D
    l2_lookup_cycles: int = 12         # private 1 MB L2
    llc_lookup_cycles: int = 30        # shared 33 MB LLC incl. fixed NOC delay

    @property
    def cache_path_cycles(self) -> int:
        return (self.core_issue_cycles + self.l1_lookup_cycles
                + self.l2_lookup_cycles + self.llc_lookup_cycles)


@dataclasses.dataclass(frozen=True)
class DramParams:
    """One memory device's geometry + timing set.

    The **default values are JEDEC DDR4-2666U (19-19-19)** as configured
    in Ramulator for the paper's platform; the preset registry
    (`repro.core.presets`) builds DDR5-4800 and HBM2e instances of the
    same dataclass.  Conventions (easy to get wrong — read this):

    * All ``t*`` timing fields are **memory bus cycles** (``tCK``),
      never nanoseconds.  One bus cycle is ``dram_ps_per_clk``
      picoseconds (750 ps for DDR4-2666).
    * ``mt_per_s`` is the data rate in mega-transfers/s — **two**
      transfers per bus cycle (DDR), so
      ``mt_per_s == 2e6 / dram_ps_per_clk`` up to integer rounding.
    * A *channel* here is an independently scheduled command/data
      interface: a DDR4 channel, a DDR5 **sub-channel**, or an HBM
      **pseudo-channel**.  ``bus_bytes`` is its data width (8 B for
      DDR4/HBM2e pseudo-channel, 4 B for a DDR5 sub-channel).
    * ``same_bank_refresh`` selects DDR5's REFsb rotation: each refresh
      blocks only one bank per rank for ``tRFC`` (= tRFCsb), every
      ``tREFI`` (= per-bank tREFI / banks_per_rank) ticks, instead of
      closing the whole rank.
    """

    n_channels: int = 6
    ranks_per_channel: int = 2
    banks_per_rank: int = 16           # 4 bank groups x 4 banks
    bank_groups: int = 4
    rows_per_bank: int = 1 << 17
    cols_per_row: int = 1 << 10        # 1024 columns x 8B = 8KB row
    line_bytes: int = 64
    bus_bytes: int = 8                 # channel data-bus width
    dram_ps_per_clk: int = 750         # 1 / 1.333 GHz, as in the paper
    mt_per_s: int = 2666
    same_bank_refresh: bool = False    # DDR5 REFsb rotation

    # Core timings (bus cycles @ dram_ps_per_clk)
    tCL: int = 19
    tRCD: int = 19
    tRP: int = 19
    tRAS: int = 43
    tBL: int = 4                       # burst 8, DDR -> 4 bus cycles
    tCCD_S: int = 4
    tCCD_L: int = 7
    tWR: int = 20
    tWTR_S: int = 4
    tWTR_L: int = 10
    tRTP: int = 10
    tRRD_S: int = 4
    tRRD_L: int = 7
    tFAW: int = 28
    tCWL: int = 14
    tRTRS: int = 2                     # rank-to-rank switch
    tREFI: int = 10400                 # 7.8 us
    tRFC: int = 467                    # 350 ns (16 Gb devices)

    @property
    def tRC(self) -> int:
        return self.tRAS + self.tRP

    @property
    def peak_gbs(self) -> float:
        """Theoretical peak bandwidth: channels x bus width x MT/s."""
        return self.n_channels * self.bus_bytes * self.mt_per_s * 1e6 / 1e9

    @property
    def banks_per_channel(self) -> int:
        return self.ranks_per_channel * self.banks_per_rank

    @property
    def banks_per_group(self) -> int:
        return self.banks_per_rank // self.bank_groups

    @property
    def lines_per_row(self) -> int:
        """Cache lines per DRAM row (row-buffer reach of the open page)."""
        return self.cols_per_row * 8 // self.line_bytes


@dataclasses.dataclass(frozen=True)
class PlatformParams:
    cpu: CpuParams = dataclasses.field(default_factory=CpuParams)
    dram: DramParams = dataclasses.field(default_factory=DramParams)

    @property
    def freq_ratio_exact(self) -> float:
        """CPU-to-memory frequency ratio (1.575 for 2.1/1.333 GHz)."""
        return self.dram.dram_ps_per_clk / self.cpu.cpu_ps_per_clk

    @property
    def freq_ratio_ceil(self) -> int:
        """DAMOV's integer rounding of the ratio (Code Listing 1a)."""
        import math
        return math.ceil(self.dram.dram_ps_per_clk / self.cpu.cpu_ps_per_clk)


DEFAULT_PLATFORM = PlatformParams()
