"""Network-on-chip models (paper Sec. 4, Fig. 6b).

The baseline platform folds the NOC into a fixed delay inside the LLC
latency.  The enhanced model is a Skylake-like 2-D mesh: cores and LLC
slices live on tiles of a 6x4 mesh (matching the 24-core Skylake die
layout reverse-engineered in [17]/[19]); the two integrated memory
controllers sit on opposite die edges [18].  A request traverses

    core tile -> LLC slice tile (address-hashed) -> IMC edge tile

and the response returns.  With 2 cycles/hop (1 link + 1 router stage
at 2.1 GHz) the average extra round trip over the baseline's fixed
delay is ~21 CPU cycles = 10 ns, matching the paper's measurement
(with ~4 core cycles per hop, i.e. McCalpin's ~1.9 ns/hop).

The model is evaluated *analytically* (expected hop counts over the
uniform LLC-slice hash), which is exact for Mess traffic: its address
streams hash uniformly across slices.
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np

MESH_COLS = 6
MESH_ROWS = 4
# Effective core cycles per mesh hop (link + router + slice ingress) at
# 2.1 GHz.  McCalpin's Skylake-SP measurements put a hop at ~1.9 ns,
# i.e. ~4 core cycles (the mesh runs in the slower uncore domain).
CYCLES_PER_HOP = 4


@dataclasses.dataclass(frozen=True)
class NocModel:
    kind: str                 # "fixed" | "mesh"
    req_cycles: int           # extra request-path cycles vs. baseline
    resp_cycles: int          # extra response-path cycles vs. baseline

    @property
    def round_trip_cycles(self) -> int:
        return self.req_cycles + self.resp_cycles


def _tiles():
    return list(itertools.product(range(MESH_ROWS), range(MESH_COLS)))


def _manhattan(a, b):
    return abs(a[0] - b[0]) + abs(a[1] - b[1])


def mesh_hop_stats() -> dict:
    """Expected hop counts for core->slice->IMC->core paths."""
    tiles = _tiles()
    # IMCs on the east/west die edges, middle rows (Skylake-SP layout)
    imcs = [(1, 0), (2, MESH_COLS - 1)]
    h_cs = np.mean([_manhattan(c, s) for c in tiles for s in tiles])
    h_sm = np.mean([min(_manhattan(s, m) for m in imcs) for s in tiles])
    h_mc = np.mean([min(_manhattan(m, c) for m in imcs) for c in tiles])
    return dict(core_to_slice=h_cs, slice_to_imc=h_sm, imc_to_core=h_mc)


def make_noc(kind: str) -> NocModel:
    if kind == "fixed":
        # the baseline's fixed delay is already inside the LLC latency
        return NocModel("fixed", 0, 0)
    if kind == "mesh":
        h = mesh_hop_stats()
        req = round((h["core_to_slice"] + h["slice_to_imc"])
                    * CYCLES_PER_HOP)
        resp = round(h["imc_to_core"] * CYCLES_PER_HOP)
        # subtract the fixed delay the baseline already charges
        baseline_rt = 10
        extra = max(req + resp - baseline_rt, 0)
        return NocModel("mesh",
                        req_cycles=int(round(extra * (req / (req + resp)))),
                        resp_cycles=int(extra
                                        - round(extra * (req / (req + resp)))))
    raise ValueError(f"unknown NOC kind {kind!r}")
