"""Memory-simulator backend flavors (paper Sec. 5, Fig. 7).

The paper deploys its interface corrections on three cycle-accurate
backends — Ramulator, Ramulator 2 and DRAMsim3 — and shows the fixes
are backend-agnostic.  The three C++ simulators share the DDR4 state
machine but differ in controller policy details; we model exactly those
deltas as `SchedulerPolicy` flavors over the same `dram.tick` engine:

* ``ramulator``   — FR-FCFS, open page, plain watermark write drain
                    (the paper's primary backend).
* ``ramulator2``  — adds the row-hit *starvation cap* (the BH-FRFCFS
                    scheduler of Ramulator 2): after `cap` consecutive
                    row-hit CAS grants, oldest-first wins over row-hit.
* ``dramsim3``    — deeper per-channel command queue and a wider
                    write-drain hysteresis band, per DRAMsim3 defaults.

A fourth, ``delay_buffer``, is the paper's *future work* (Sec. 5): the
studied simulators model memory-controller decisions but not the time
spent in the MC pipeline / PHY / IO.  The paper suggests a delay-buffer
that shifts the unloaded latency up to match the actual system; we
implement it as `mc_extra_ticks` on top of any flavor (stage 10).
"""
from __future__ import annotations

from repro.core.dram import SchedulerPolicy

#: Measured MC-pipeline + PHY + IO time the studied simulators omit
#: (paper Sec. 5).  ~22 ns => 29 DRAM ticks at 750 ps.
MC_PHY_TICKS = 29

BACKENDS = {
    "ramulator": SchedulerPolicy(
        name="ramulator", queue_depth=256, drain_hi=20, drain_lo=6,
        row_hit_cap=0),
    "ramulator2": SchedulerPolicy(
        name="ramulator2", queue_depth=256, drain_hi=20, drain_lo=6,
        row_hit_cap=4),
    "dramsim3": SchedulerPolicy(
        name="dramsim3", queue_depth=256, drain_hi=30, drain_lo=10,
        row_hit_cap=0),
}


def make_policy(backend: str = "ramulator",
                delay_buffer: bool = False) -> SchedulerPolicy:
    try:
        base = BACKENDS[backend]
    except KeyError:
        raise ValueError(
            f"unknown backend {backend!r}; one of {sorted(BACKENDS)}"
        ) from None
    if delay_buffer:
        import dataclasses
        base = dataclasses.replace(base, mc_extra_ticks=MC_PHY_TICKS)
    return base
