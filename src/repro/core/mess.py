"""Mess-style bandwidth-latency characterization (paper Sec. 2, Fig. 2-7).

The Mess benchmark [5] profiles a memory system as a *family of
bandwidth-latency curves*: for each read/write traffic mix, sweep the
injected bandwidth from unloaded to saturation and record the latency a
pointer-chase probe observes.  Every figure in the paper is such a
sweep evaluated at one simulation stage, plotted from each of the three
views.

This module drives `platform.run_point` over the (pace x write-mix)
grid.  Pace points are `vmap`-ed — one XLA program simulates the whole
curve — and the pace axis is sharded across every available device via
`repro.core.shard.sharded_vmap` (plain vmap on one device, bit-
identical either way).  Write mixes iterate in Python (they change
traffic shape, not shapes of arrays, but keeping the grid 1-D per
compile keeps XLA compile time low and matches how Mess runs on real
hardware: one process per mix).

Outputs are plain numpy arrays, written as CSV by the benchmark harness
in the artifact's `bandwidth_latency.csv` format.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.platform import StageConfig, run_point
from repro.core.shard import sharded_vmap

#: write-fraction numerators out of 64 -> read fractions 100..50%
#: (Mess plots 100%-read lightest to 50%-read darkest).
WRITE_MIXES = (0, 8, 16, 24, 32)
#: demand requests per traffic core per window; 23 traffic cores,
#: 64 B lines, 1000 cycles at 2.1 GHz => pace 64 ~ 198 GB/s offered.
#: Offered bandwidth scales with `StageConfig.n_sockets`: a second
#: socket (47 traffic cores) makes pace 64 ~ 404 GB/s — the knob that
#: drives HBM2e past the single-socket frontend ceiling.
DEFAULT_PACES = (1, 2, 4, 6, 8, 12, 16, 20, 24, 28, 32, 40, 48, 64)


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """One stage's Mess characterization, all three views."""

    stage: str
    write_mixes: tuple
    paces: tuple
    # each (n_mixes, n_paces) float arrays
    sim_bw: np.ndarray
    sim_lat: np.ndarray
    if_bw: np.ndarray
    if_lat: np.ndarray
    app_bw: np.ndarray
    app_lat: np.ndarray
    chase_lat: np.ndarray

    def view(self, which: str):
        """(bw GB/s, lat ns) arrays for 'sim' | 'if' | 'app'."""
        return (getattr(self, f"{which}_bw"), getattr(self, f"{which}_lat"))

    def read_fraction(self, i: int) -> float:
        return 1.0 - self.write_mixes[i] / 64.0

    def to_rows(self):
        """Rows in the artifact's bandwidth_latency.csv format."""
        rows = []
        for i, wr in enumerate(self.write_mixes):
            for j, pace in enumerate(self.paces):
                rows.append(dict(
                    stage=self.stage, read_pct=round(100 * (1 - wr / 64)),
                    pace=pace,
                    sim_bw_gbs=self.sim_bw[i, j], sim_lat_ns=self.sim_lat[i, j],
                    if_bw_gbs=self.if_bw[i, j], if_lat_ns=self.if_lat[i, j],
                    app_bw_gbs=self.app_bw[i, j], app_lat_ns=self.app_lat[i, j],
                ))
        return rows


@functools.lru_cache(maxsize=None)
def _sweep_fn(cfg: StageConfig):
    """One compiled program: device-sharded vmap over pace points.

    The batched argument is a ``(pace, wr_num)`` pair with both leaves
    batched, so one compile serves every write mix and the pace axis
    shards across devices (vmap fallback on one device).  The pair is
    **donated**: `sweep` rebuilds it per mix, so XLA may alias the
    per-point buffers into the outputs instead of copying them.
    """
    return sharded_vmap(lambda pw: run_point(cfg, pw[0], pw[1]),
                        donate=True)


#: measured events/window calibration, keyed on the *device* (the
#: hashable `DramParams`) and stage name: ``(per_pace, fixed)`` linear
#: coefficients from `load_event_calibration`.  Routing only — the
#: exact ``weave_sat`` backstop means a stale entry costs speed, never
#: correctness.
_EVENT_CAL: dict = {}

#: safety margin over the measured fit: refresh beats and drain-phase
#: wander shift per-window event counts between workloads, so route a
#: point to the event engine only with measured headroom to spare.
CAL_MARGIN = 1.35


def load_event_calibration(path: str | None = None) -> int:
    """Load measured events/window fits from a ``BENCH_weave.json``.

    `benchmarks.weave_bench` fits ``events/window ~ per_pace * pace +
    fixed`` per device preset from the compiled event engine's own
    ``weave_events`` diagnostics (the ROADMAP "event-engine tuning"
    item); this registers those fits so `event_covers` routes pace
    points on *measured* rates instead of the conservative closed-form
    bound.  Entries key on ``(DramParams, stage_name)``, so a
    calibration for one device never routes another.

    Args:
        path: report path; defaults to the repo's checked-in
            ``reports/benchmarks/BENCH_weave.json``.
    Returns:
        The number of calibration entries registered (0 when the
        report is missing or carries no fits — routing falls back to
        the closed-form estimate, unchanged behavior).
    """
    import json
    import pathlib

    from repro.core.presets import PRESETS, platform_for

    if path is None:
        path = (pathlib.Path(__file__).resolve().parents[3]
                / "reports" / "benchmarks" / "BENCH_weave.json")
    path = pathlib.Path(path)
    if not path.exists():
        return 0
    report = json.loads(path.read_text())
    stage = report.get("stage", "")
    n = 0
    for preset, row in report.get("presets", {}).items():
        fit = row.get("event_rate_fit")
        if not fit or preset not in PRESETS:
            continue
        _EVENT_CAL[(platform_for(preset).dram, stage)] = (
            float(fit["per_pace"]), float(fit["fixed"]))
        n += 1
    return n


_CAL_LOADED = False


def _ensure_calibration():
    """Lazily register the checked-in calibration once per process (a
    malformed or missing report must never break a sweep — routing
    falls back to the closed-form bound)."""
    global _CAL_LOADED
    if not _CAL_LOADED:
        _CAL_LOADED = True
        try:
            load_event_calibration()
        except (OSError, ValueError, KeyError, TypeError):
            pass


def event_covers(cfg: StageConfig, pace: int) -> bool:
    """Static estimate: does the event budget cover this pace's events?

    Per window, a pace-``p`` point offers ``p * n_traffic`` requests
    over ``C`` channels; each needs at most ~3 commands (PRE+ACT+CAS
    on a row miss), plus ~``p`` arrival bursts and fixed chase-probe /
    refresh / drain-settle headroom.  When a measured calibration is
    registered for this device and stage (`load_event_calibration`),
    the fitted events/window rate (x `CAL_MARGIN` safety) replaces the
    closed-form bound.  Used by `sweep` to route points between the
    engines; deliberately conservative (command ticks coalesce across
    channels in practice), and backstopped at runtime by the exact
    ``weave_sat`` flag — a mis-routed point is re-run dense, so
    routing affects speed, never results.
    """
    wcfg = cfg.workload_config()
    dram = cfg.platform.dram
    cal = _EVENT_CAL.get((dram, cfg.name))
    if cal is not None:
        a, b = cal
        est = int((a * pace + max(b, 0.0)) * CAL_MARGIN) + 1
    else:
        est = (3 * pace * wcfg.n_traffic) // dram.n_channels + pace + 64
    return est <= cfg.event_budget()


def _run_mix(cfg: StageConfig, paces, wr):
    """One write-mix row, knee-routed between the weave engines.

    With ``cfg.weave == "event"``, pace points whose event budget
    provably suffices (`event_covers`) run the event engine; the
    saturated tail runs the dense reference.  Any event-routed point
    that still reports budget saturation (``weave_sat``) is re-run
    dense — the row is **bit-identical to an all-dense sweep by
    construction**, the event engine only buys wall-clock where its
    semantics are exact.
    """
    n = len(paces)
    if cfg.cmd_trace:
        # the per-step `cmd_*` records have engine-dependent step-axis
        # shapes (dense: ticks/window, event: budget), so the knee-
        # routed engine merge below cannot column-stack them; record
        # command streams through `platform.run_frontend` +
        # `repro.oracle.extract_stream` on a single engine instead
        raise ValueError("cmd_trace is unsupported in mess.sweep's "
                         "knee-routed engine mix; run run_frontend "
                         "with an explicit weave engine instead")
    if cfg.weave != "event":
        pace_v = jnp.asarray(paces, jnp.int32)
        return jax.device_get(_sweep_fn(cfg)(
            (pace_v, jnp.full_like(pace_v, wr))))

    _ensure_calibration()
    cfg_dense = dataclasses.replace(cfg, weave="dense")
    ev = [i for i, p in enumerate(paces) if event_covers(cfg, p)]
    dn = [i for i in range(n) if i not in ev]
    parts = {}
    if ev:
        pv = jnp.asarray([paces[i] for i in ev], jnp.int32)
        out = jax.device_get(_sweep_fn(cfg)((pv, jnp.full_like(pv, wr))))
        sat = np.asarray(out["weave_sat"]) > 0
        if sat.any():                      # estimator missed: go exact
            dn += [ev[j] for j in np.flatnonzero(sat)]
            ev = [ev[j] for j in np.flatnonzero(~sat)]
            out = {k: np.asarray(v)[~sat] for k, v in out.items()}
        parts["ev"] = (ev, out)
    if dn:
        pv = jnp.asarray([paces[i] for i in dn], jnp.int32)
        parts["dn"] = (dn, jax.device_get(_sweep_fn(cfg_dense)(
            (pv, jnp.full_like(pv, wr)))))
    first = next(iter(parts.values()))[1]
    merged = {}
    for k in first:
        proto = np.asarray(first[k])
        col = np.empty((n,) + proto.shape[1:], proto.dtype)
        for (idx, v) in parts.values():
            col[np.asarray(idx, int)] = np.asarray(v[k])
        merged[k] = col
    return merged


def sweep(cfg: StageConfig, paces=DEFAULT_PACES,
          write_mixes=WRITE_MIXES) -> SweepResult:
    """Run the Mess characterization of one simulation stage.

    Under the default event weave engine the pace axis is knee-routed
    (`_run_mix`): below-knee points take the fast event scan, the
    saturated tail takes the dense reference, and saturation-flagged
    points fall back — results are bit-identical to an all-dense sweep
    regardless of ``cfg.weave``.
    """
    acc = {k: [] for k in ("sim_bw", "sim_lat", "if_bw", "if_lat",
                           "app_bw", "app_lat", "chase_lat")}
    for wr in write_mixes:
        out = _run_mix(cfg, tuple(paces), wr)
        acc["sim_bw"].append(out["sim_bw_gbs"])
        acc["sim_lat"].append(out["sim_lat_ns"])
        acc["if_bw"].append(out["if_bw_gbs"])
        acc["if_lat"].append(out["if_lat_ns"])
        acc["app_bw"].append(out["app_bw_gbs"])
        acc["app_lat"].append(out["app_lat_ns"])
        acc["chase_lat"].append(out["chase_lat_ns"])
    return SweepResult(
        stage=cfg.name, write_mixes=tuple(write_mixes), paces=tuple(paces),
        **{k: np.stack(v) for k, v in acc.items()})


def unloaded_latency_ns(res: SweepResult, view: str = "app") -> float:
    """Latency of the lowest-bandwidth 100%-read point."""
    _, lat = res.view(view)
    return float(lat[0, 0])


def max_bandwidth_gbs(res: SweepResult, view: str = "app",
                      mix_index: int = 0) -> float:
    bw, _ = res.view(view)
    return float(np.max(bw[mix_index]))


def saturated_latency_ns(res: SweepResult, view: str = "app",
                         mix_index: int = 0) -> float:
    bw, lat = res.view(view)
    return float(lat[mix_index, int(np.argmax(bw[mix_index]))])
