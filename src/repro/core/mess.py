"""Mess-style bandwidth-latency characterization (paper Sec. 2, Fig. 2-7).

The Mess benchmark [5] profiles a memory system as a *family of
bandwidth-latency curves*: for each read/write traffic mix, sweep the
injected bandwidth from unloaded to saturation and record the latency a
pointer-chase probe observes.  Every figure in the paper is such a
sweep evaluated at one simulation stage, plotted from each of the three
views.

This module drives `platform.run_point` over the (pace x write-mix)
grid.  Pace points are `vmap`-ed — one XLA program simulates the whole
curve — and the pace axis is sharded across every available device via
`repro.core.shard.sharded_vmap` (plain vmap on one device, bit-
identical either way).  Write mixes iterate in Python (they change
traffic shape, not shapes of arrays, but keeping the grid 1-D per
compile keeps XLA compile time low and matches how Mess runs on real
hardware: one process per mix).

Outputs are plain numpy arrays, written as CSV by the benchmark harness
in the artifact's `bandwidth_latency.csv` format.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.platform import StageConfig, run_point
from repro.core.shard import sharded_vmap

#: write-fraction numerators out of 64 -> read fractions 100..50%
#: (Mess plots 100%-read lightest to 50%-read darkest).
WRITE_MIXES = (0, 8, 16, 24, 32)
#: demand requests per traffic core per window; 23 traffic cores,
#: 64 B lines, 1000 cycles at 2.1 GHz => pace 64 ~ 198 GB/s offered.
#: Offered bandwidth scales with `StageConfig.n_sockets`: a second
#: socket (47 traffic cores) makes pace 64 ~ 404 GB/s — the knob that
#: drives HBM2e past the single-socket frontend ceiling.
DEFAULT_PACES = (1, 2, 4, 6, 8, 12, 16, 20, 24, 28, 32, 40, 48, 64)


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """One stage's Mess characterization, all three views."""

    stage: str
    write_mixes: tuple
    paces: tuple
    # each (n_mixes, n_paces) float arrays
    sim_bw: np.ndarray
    sim_lat: np.ndarray
    if_bw: np.ndarray
    if_lat: np.ndarray
    app_bw: np.ndarray
    app_lat: np.ndarray
    chase_lat: np.ndarray

    def view(self, which: str):
        """(bw GB/s, lat ns) arrays for 'sim' | 'if' | 'app'."""
        return (getattr(self, f"{which}_bw"), getattr(self, f"{which}_lat"))

    def read_fraction(self, i: int) -> float:
        return 1.0 - self.write_mixes[i] / 64.0

    def to_rows(self):
        """Rows in the artifact's bandwidth_latency.csv format."""
        rows = []
        for i, wr in enumerate(self.write_mixes):
            for j, pace in enumerate(self.paces):
                rows.append(dict(
                    stage=self.stage, read_pct=round(100 * (1 - wr / 64)),
                    pace=pace,
                    sim_bw_gbs=self.sim_bw[i, j], sim_lat_ns=self.sim_lat[i, j],
                    if_bw_gbs=self.if_bw[i, j], if_lat_ns=self.if_lat[i, j],
                    app_bw_gbs=self.app_bw[i, j], app_lat_ns=self.app_lat[i, j],
                ))
        return rows


@functools.lru_cache(maxsize=None)
def _sweep_fn(cfg: StageConfig):
    """One compiled program: device-sharded vmap over pace points.

    The batched argument is a ``(pace, wr_num)`` pair with both leaves
    batched, so one compile serves every write mix and the pace axis
    shards across devices (vmap fallback on one device).
    """
    return sharded_vmap(lambda pw: run_point(cfg, pw[0], pw[1]))


def sweep(cfg: StageConfig, paces=DEFAULT_PACES,
          write_mixes=WRITE_MIXES) -> SweepResult:
    """Run the Mess characterization of one simulation stage."""
    fn = _sweep_fn(cfg)
    pace_v = jnp.asarray(paces, jnp.int32)
    acc = {k: [] for k in ("sim_bw", "sim_lat", "if_bw", "if_lat",
                           "app_bw", "app_lat", "chase_lat")}
    for wr in write_mixes:
        out = jax.device_get(fn((pace_v, jnp.full_like(pace_v, wr))))
        acc["sim_bw"].append(out["sim_bw_gbs"])
        acc["sim_lat"].append(out["sim_lat_ns"])
        acc["if_bw"].append(out["if_bw_gbs"])
        acc["if_lat"].append(out["if_lat_ns"])
        acc["app_bw"].append(out["app_bw_gbs"])
        acc["app_lat"].append(out["app_lat_ns"])
        acc["chase_lat"].append(out["chase_lat_ns"])
    return SweepResult(
        stage=cfg.name, write_mixes=tuple(write_mixes), paces=tuple(paces),
        **{k: np.stack(v) for k, v in acc.items()})


def unloaded_latency_ns(res: SweepResult, view: str = "app") -> float:
    """Latency of the lowest-bandwidth 100%-read point."""
    _, lat = res.view(view)
    return float(lat[0, 0])


def max_bandwidth_gbs(res: SweepResult, view: str = "app",
                      mix_index: int = 0) -> float:
    bw, _ = res.view(view)
    return float(np.max(bw[mix_index]))


def saturated_latency_ns(res: SweepResult, view: str = "app",
                         mix_index: int = 0) -> float:
    bw, lat = res.view(view)
    return float(lat[mix_index, int(np.argmax(bw[mix_index]))])
