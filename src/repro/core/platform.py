"""The integrated simulation platform: bound/weave windows + interface.

This is the JAX equivalent of ZSim (event-based CPU frontend) connected
to a cycle-accurate memory simulator through the CPU-memory interface —
the structure of Fig. 1.  One `run_point` simulates the platform for a
fixed number of 1000-cycle ZSim windows at one Mess operating point
(pace, read/write mix) and returns the three memory-performance views.

Per window:

1. **Bound phase** (`workload.generate`): every core's memory requests
   are generated against the *immediate-response* latency.  In the
   DAMOV baseline this latency is one CPU cycle; with the paper's
   correction it is the PI-controlled estimate (Sec. 3.4).
2. **Interface** (`workload.inject_queue` + `clocking`): requests cross the
   CPU->memory clock domain under the selected clocking model
   (broken / integer-ratio / picosecond).
3. **Weave phase** (`dram.tick` scan): the cycle-accurate backend
   processes the window's DRAM ticks; completion statistics feed the
   memory-simulator and interface views.
4. **PI update**: the immediate-response latency for the next window is
   0.95*previous + 0.05*(average weave latency) — paper Sec. 3.4.

The decoupling bug is inherent to the structure (as in ZSim): the app
view's load-to-use latency is `cache_path + immediate_response`, fixed
at bound-phase time, regardless of what the weave phase later computes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import dram, workload
from repro.core.clocking import ClockModel, make_clock
from repro.core.dram import SchedulerPolicy
from repro.core.noc import NocModel, make_noc
from repro.core.timing import PlatformParams, DEFAULT_PLATFORM
from repro.core.workload import WorkloadConfig

PI_KEEP = 0.95       # paper: 95% previous estimate
PI_BLEND = 0.05      # paper: 5% new cycle-accurate average


@dataclasses.dataclass(frozen=True)
class StageConfig:
    """Full static configuration of one simulation stage.

    Every field is static (hashable): one `StageConfig` = one XLA
    program shape.  ``platform`` carries the CPU params and the memory
    device (`DramParams` — the DDR4-2666 default or any preset from
    `repro.core.presets`); ``l_ir_init_cycles`` is in CPU cycles,
    ``windows``/``warmup`` count 1000-cycle ZSim windows.
    """

    name: str = "01-baseline"
    clock_mode: str = "broken_noscale"
    mapping: str = "simple"
    pi_latency: bool = False          # stage 04 model correction
    noc: str = "fixed"                # stage 06
    prefetch: bool = False            # stage 07
    policy: SchedulerPolicy = dataclasses.field(default_factory=SchedulerPolicy)
    l_ir_init_cycles: float = 1.0     # DAMOV immediate-response latency
    windows: int = 96
    warmup: int = 32
    #: weave engine: ``"event"`` (default) scans a static *event budget*
    #: per window, jumping straight to the next tick where eligibility
    #: can change (`dram.next_event`) — bit-identical to ``"dense"``,
    #: the reference one-tick-per-step scan, as long as the budget
    #: covers the window's events (saturation is reported in the
    #: ``weave_sat`` view, never silent).
    weave: str = "event"
    #: event-scan steps per window; 0 derives the budget from bus
    #: occupancy (`clocking.event_budget`).
    weave_events: int = 0
    #: traffic sockets: each adds 24 frontend cores (one shared chase
    #: probe overall).  2 sockets double the frontend issue capacity —
    #: required to drive HBM2e past the single-socket ~200 GB/s ceiling.
    n_sockets: int = 1
    #: multi-socket channel ownership: "interleaved" (all sockets hit
    #: all channels) or "partitioned" (n_channels/n_sockets per socket).
    socket_channels: str = "interleaved"
    #: three-perspective telemetry (`repro.obs`): when True, the weave
    #: loop accumulates per-channel command-mix counter planes and
    #: log2 latency histograms (`dram.TickTele`) and the window step
    #: samples interface-view series (queue depth, MSHR budget, PI
    #: estimate), all emitted as ``tele_*`` keys in the views.  Static
    #: flag, off by default: the False path traces the exact historical
    #: graph, so all outputs stay bit-identical and free when off.
    telemetry: bool = False
    #: command-stream recorder (`repro.oracle`): when True, every weave
    #: step also emits the granted DRAM command (`dram.TickCmd` — code,
    #: grant tick, bank, row, refresh firings) as ``cmd_*`` keys in the
    #: views, ready for `repro.oracle.extract_stream` and the protocol-
    #: legality checker.  Static flag like ``telemetry``: the False
    #: path traces the exact historical graph, and because both weave
    #: engines evaluate exactly the grant ticks, the recorded streams
    #: are engine-invariant.
    cmd_trace: bool = False
    platform: PlatformParams = dataclasses.field(
        default_factory=lambda: DEFAULT_PLATFORM)

    def __post_init__(self):
        if self.weave not in ("dense", "event"):
            raise ValueError(
                f"weave must be 'dense' or 'event', got {self.weave!r}")

    def clock(self) -> ClockModel:
        return make_clock(self.clock_mode, self.platform)

    def event_budget(self) -> int:
        """Event-scan steps per window (override or clock-derived)."""
        return self.weave_events or self.clock().events_per_window_static

    def noc_model(self) -> NocModel:
        return make_noc(self.noc)

    def workload_config(self) -> WorkloadConfig:
        n = self.noc_model()
        return WorkloadConfig(
            mapping=self.mapping, prefetch=self.prefetch,
            cache_path_cycles=self.platform.cpu.cache_path_cycles,
            noc_req_cycles=n.req_cycles, noc_resp_cycles=n.resp_cycles,
            dram=self.platform.dram, n_sockets=self.n_sockets,
            socket_channels=self.socket_channels)


class WindowOut(NamedTuple):
    served_rd: jnp.ndarray
    served_wr: jnp.ndarray
    sum_rd_lat_ticks: jnp.ndarray
    sum_if_lat_ps: jnp.ndarray
    chase_rd: jnp.ndarray
    sum_chase_lat_ticks: jnp.ndarray
    app_lat_cycles: jnp.ndarray     # bound-phase load-to-use (app view)
    l_ir: jnp.ndarray
    injected: jnp.ndarray
    ticks: jnp.ndarray
    progress: jnp.ndarray           # frontend progress marker (traces)


def _window_step(cfg: StageConfig, clock: ClockModel, wcfg: WorkloadConfig,
                 frontend, carry, w):
    queue, banks, fstate, l_ir, lat_est, tstate = carry
    cpu = cfg.platform.cpu
    l_ir_cycles = jnp.maximum(jnp.round(l_ir).astype(jnp.int32), 1)
    window_ps = cpu.window_cycles * cpu.cpu_ps_per_clk

    # bound phase + interface hand-off (MSHR closed-loop budget)
    budget = workload.littles_law_budget(lat_est, window_ps)
    cand, aux = frontend.bound(fstate, l_ir_cycles, budget,
                               cpu.window_cycles)
    queue, acc_demand, injected = workload.inject_queue(queue, cand,
                                                        clock, w, wcfg)
    fstate = frontend.update(fstate, aux, acc_demand)
    if cfg.telemetry:
        # interface-view series: per-channel queue depth right after
        # this window's injection (window boundaries are engine-
        # invariant, so the sample is identical under dense and event)
        inject_depth = jnp.sum(queue.valid, axis=1)

    # weave phase: cycle-accurate DRAM simulation of this window's ticks
    start = clock.window_start_tick(w)
    end = clock.window_end_tick(w)
    planes = dram.bank_planes(cfg.platform.dram)
    tick_fn = functools.partial(
        dram.tick, dram=cfg.platform.dram, policy=cfg.policy,
        tick2cpu_num=clock.tick_to_cpu_ps_num,
        tick2cpu_den=clock.tick_to_cpu_ps_den,
        cpu_ps_per_clk=cpu.cpu_ps_per_clk, planes=planes,
        telemetry=cfg.telemetry, cmd_trace=cfg.cmd_trace)

    # Stats accumulate (C,)-per-channel in the scan *carry*, in time
    # order per channel — idle ticks add exact zeros (the float32
    # identity), so window totals are bit-identical across engines.
    # With telemetry on, the integer `TickTele` planes accumulate in
    # the same carry (ints commute, so the planes are engine-exact).
    acc0 = dram.zero_stats(cfg.platform.dram)
    tacc0 = dram.zero_tele(cfg.platform.dram) if cfg.telemetry else None
    tree_add = functools.partial(jax.tree_util.tree_map, jnp.add)

    # Both scan bodies below are written once for all four flag
    # combinations: `None` is an *empty* pytree node, so a disabled
    # flag's carry slot / ys slot contributes no leaves and the traced
    # graph is exactly the historical flags-off one.
    def split_extras(rest):
        """Unpack `dram.tick`'s flag-dependent return tail."""
        ti = ts = cmd = None
        if cfg.telemetry:
            ti, ts, rest = rest[0], rest[1], rest[2:]
        if cfg.cmd_trace:
            cmd = rest[0]
        return ti, ts, cmd

    if cfg.weave == "dense":
        # reference engine: one scan step per DRAM tick
        def body(qba, i):
            q, b, acc, tacc, ts = qba
            t = start + i
            q, b, s, *rest = tick_fn(q, b, t, active=t < end, tele=ts)
            ti, ts, cmd = split_extras(rest)
            return (q, b, tree_add(acc, s), tree_add(tacc, ti), ts), cmd

        (queue, banks, st, tacc, tstate), cmds = jax.lax.scan(
            body, (queue, banks, acc0, tacc0, tstate),
            jnp.arange(clock.ticks_per_window_static, dtype=jnp.int32))
        weave_events = end - start
        weave_sat = jnp.zeros((), bool)
    else:
        # event-horizon engine: each step jumps every channel to its
        # own next tick where eligibility can change (`dram.next_event`
        # is per-channel-exact; `dram.tick` couples channels only
        # through the stats reduction) and applies `tick` there.  A
        # channel whose events are exhausted (tn == horizon) parks at
        # horizon-1 with `active=False`, which freezes its state just
        # like the dense scan's inactive tail ticks.
        horizon = start + clock.ticks_per_window_static
        nev_fn = functools.partial(
            dram.next_event, dram=cfg.platform.dram, policy=cfg.policy,
            planes=planes)
        t0 = jnp.full((cfg.platform.dram.n_channels,), 1, jnp.int32)

        def ebody(qbta, i):
            q, b, t, acc, tacc, ts = qbta
            tn = nev_fn(q, b, t, horizon)           # (C,)
            live = tn < horizon
            tau = jnp.minimum(tn, horizon - 1)
            q, b, s, *rest = tick_fn(q, b, tau,
                                     active=live & (tau < end), tele=ts)
            ti, ts, cmd = split_extras(rest)
            return (q, b, tau, tree_add(acc, s),
                    tree_add(tacc, ti), ts), (tn < end, cmd)

        (queue, banks, t_last, st, tacc, tstate), (live, cmds) = jax.lax.scan(
            ebody, (queue, banks, t0 * (start - 1), acc0, tacc0, tstate),
            jnp.arange(cfg.event_budget(), dtype=jnp.int32))
        # the binding constraint is the busiest channel's event count
        weave_events = jnp.max(jnp.sum(live.astype(jnp.int32), axis=0))
        # budget exhausted with events still pending anywhere before
        # the static horizon: spilled events replay next window
        # (graceful) and the window is flagged — never silent.  The
        # check runs against `horizon`, not `end`: a pending *tail*
        # event (an arrival in [end, horizon)) carries a drain-
        # hysteresis update the dense scan's inactive ticks would have
        # applied, so skipping it must flag too, or the sat=0 =>
        # bit-identical contract (relied on by `mess._run_mix` and
        # `traces.replay._replay_exact`) would leak a silent
        # divergence into the next window.
        weave_sat = jnp.any(nev_fn(queue, banks, t_last, horizon) < horizon)

    n_rd = jnp.sum(st.served_rd)
    sum_if = jnp.sum(st.sum_if_lat_ps)

    # Closed-loop latency estimate for the next window's MSHR budget:
    # load-to-use ~ cache path + weave round trip (sim domain).
    lat_w = (jnp.sum(st.sum_rd_lat_ticks) / jnp.maximum(n_rd, 1)
             * cfg.platform.dram.dram_ps_per_clk
             + wcfg.cache_path_cycles * cpu.cpu_ps_per_clk)
    lat_est = jnp.where(n_rd > 0, 0.5 * lat_est + 0.5 * lat_w, lat_est)

    # PI controller (Sec. 3.4): blend in the weave-phase average latency
    avg_if_cycles = sum_if / (cpu.cpu_ps_per_clk * jnp.maximum(n_rd, 1))
    l_ir_next = jnp.where(
        jnp.logical_and(cfg.pi_latency, n_rd > 0),
        PI_KEEP * l_ir + PI_BLEND * avg_if_cycles, l_ir)

    noc_rt = wcfg.noc_req_cycles + wcfg.noc_resp_cycles
    app_lat_cycles = (wcfg.cache_path_cycles + noc_rt
                      + l_ir_cycles).astype(jnp.float32)

    out = WindowOut(
        served_rd=n_rd, served_wr=jnp.sum(st.served_wr),
        sum_rd_lat_ticks=jnp.sum(st.sum_rd_lat_ticks),
        sum_if_lat_ps=sum_if,
        chase_rd=jnp.sum(st.chase_rd),
        sum_chase_lat_ticks=jnp.sum(st.sum_chase_lat_ticks),
        app_lat_cycles=app_lat_cycles, l_ir=l_ir_next,
        injected=injected, ticks=end - start,
        progress=frontend.progress(fstate))
    # weave-engine diagnostics ride next to WindowOut (not inside it, so
    # the per-window trajectory stays bit-identical across engines):
    # evaluated event ticks this window + the budget-saturation flag.
    diag = dict(weave_events=weave_events, weave_sat=weave_sat)
    if cfg.telemetry:
        # the three-perspective telemetry planes (`repro.obs`): the
        # per-window DRAM counter/histogram planes plus the interface-
        # view series sampled at window boundaries.  All integer
        # counters are *event-accounted* (at command grants, refresh
        # deadlines, drain flips), so both weave engines accumulate
        # identical window totals.
        diag.update({f"tele_{k}": v for k, v in tacc._asdict().items()},
                    tele_queue_depth=inject_depth,
                    tele_mshr_budget=budget,
                    tele_lat_est_ps=lat_est)
    if cfg.cmd_trace:
        # the per-step command record (`repro.oracle`): the ys axis is
        # the weave scan's step axis (dense: one slot per tick; event:
        # one per budget step), so a window's record is dense in steps
        # but sparse in commands — `repro.oracle.extract_stream`
        # filters the NONE slots and flattens to a time-ordered stream.
        diag.update({f"cmd_{k}": v for k, v in cmds._asdict().items()})
    return (queue, banks, fstate, l_ir_next, lat_est, tstate), (out, diag)


def run_frontend(cfg: StageConfig, frontend):
    """Simulate the platform driven by any bound-phase frontend.

    Args:
        cfg: static stage configuration (one XLA program per value).
        frontend: object following the protocol documented on
            `workload.MessFrontend`; it may close over traced arrays,
            so this function is `vmap`-able — and thus shardable via
            `repro.core.shard.sharded_vmap` — across operating points
            (Mess) or applications (trace replay).
    Returns:
        ``(views, outs)``: the aggregated three-view dict of scalars
        (bandwidths in GB/s, latencies in ns — see `_aggregate` for
        which clock domain each view reads) plus the raw per-window
        `WindowOut` trajectory (used by the replay engine to locate
        the trace-completion window).
    """
    clock = cfg.clock()
    wcfg = cfg.workload_config()
    queue = dram.init_queue(cfg.platform.dram, cfg.policy,
                            n_sockets=cfg.n_sockets)
    banks = dram.init_banks(cfg.platform.dram)
    fstate = frontend.init_state()
    l_ir0 = jnp.asarray(cfg.l_ir_init_cycles, jnp.float32)
    # optimistic unloaded estimate; the EMA converges within warmup
    lat_est0 = jnp.asarray(
        (cfg.platform.cpu.cache_path_cycles
         * cfg.platform.cpu.cpu_ps_per_clk)
        + (cfg.platform.dram.tCL + cfg.platform.dram.tBL)
        * cfg.platform.dram.dram_ps_per_clk, jnp.float32)

    step = functools.partial(_window_step, cfg, clock, wcfg, frontend)
    # the trailing telemetry-state slot is None (an empty pytree node)
    # when telemetry is off, keeping the flags-off graph historical
    carry0 = (queue, banks, fstate, l_ir0, lat_est0,
              dram.init_tele(cfg.platform.dram) if cfg.telemetry else None)
    _, (outs, diag) = jax.lax.scan(
        step, carry0, jnp.arange(cfg.windows, dtype=jnp.int32))
    return _aggregate(cfg, outs, diag), outs


def run_point(cfg: StageConfig, pace, wr_num):
    """Simulate one Mess operating point; returns the three views.

    Args:
        cfg: static stage configuration.
        pace: demand requests / traffic core / window
            (int32, traced — vmap-able).
        wr_num: write-fraction numerator out of 64 (int32, traced).
    Returns:
        The three-view dict: ``sim_bw_gbs`` / ``if_bw_gbs`` /
        ``app_bw_gbs`` in GB/s, ``sim_lat_ns`` / ``if_lat_ns`` /
        ``app_lat_ns`` / ``chase_lat_ns`` in ns, plus diagnostics
        (``n_rd``/``n_wr`` served counts, ``l_ir_final`` in CPU
        cycles, ``injected`` accepted requests).
    """
    frontend = workload.MessFrontend(pace, wr_num, cfg.workload_config())
    views, _ = run_frontend(cfg, frontend)
    return views


def _aggregate(cfg: StageConfig, outs: WindowOut, diag=None):
    """Post-warmup aggregation of the three views.

    Units: bandwidths GB/s; latencies ns.  View ① (simulator) counts
    time in DRAM ticks x ``dram_ps_per_clk``; view ② (interface) in
    CPU-perceived picoseconds across the clock-domain crossing; view ③
    (application) in CPU cycles x ``cpu_ps_per_clk`` of bound-phase
    load-to-use latency.
    """
    # aggregate post-warmup
    keep = jnp.arange(cfg.windows) >= cfg.warmup
    def ksum(x):
        return jnp.sum(jnp.where(keep, x, 0))
    line = cfg.platform.dram.line_bytes
    cpu = cfg.platform.cpu

    n_rd = ksum(outs.served_rd)
    n_wr = ksum(outs.served_wr)
    bytes_served = (n_rd + n_wr).astype(jnp.float32) * line
    ticks = ksum(outs.ticks).astype(jnp.float32)
    cpu_ps = (jnp.sum(keep) * cpu.window_cycles
              * cpu.cpu_ps_per_clk).astype(jnp.float32)
    sim_ps = ticks * cfg.platform.dram.dram_ps_per_clk

    nz = jnp.maximum(n_rd, 1).astype(jnp.float32)
    # weave-engine diagnostics: evaluated event ticks post-warmup and
    # the count of budget-saturated windows (anywhere in the run —
    # warmup saturation perturbs the converged state too).  The dense
    # engine reports its active tick count and never saturates.
    if diag is None:
        weave_events = ticks.astype(jnp.int32)
        weave_sat = jnp.zeros((), jnp.int32)
    else:
        weave_events = ksum(diag["weave_events"])
        weave_sat = jnp.sum(diag["weave_sat"].astype(jnp.int32))
    # bytes/ps -> GB/s is a factor of 1e3 (1e12 ps/s over 1e9 B/GB)
    return dict(
        # ① memory-simulator view (DRAM's own clock domain, from the MC)
        sim_bw_gbs=bytes_served / sim_ps * 1e3,
        sim_lat_ns=ksum(outs.sum_rd_lat_ticks).astype(jnp.float32)
            * (cfg.platform.dram.dram_ps_per_clk * 1e-3) / nz,
        # ② memory-interface view (CPU-perceived clock domain)
        if_bw_gbs=bytes_served / cpu_ps * 1e3,
        if_lat_ns=ksum(outs.sum_if_lat_ps) * 1e-3 / nz,
        # ③ application view (bound-phase load-to-use; the outcome)
        app_bw_gbs=bytes_served / cpu_ps * 1e3,
        app_lat_ns=jnp.sum(jnp.where(keep, outs.app_lat_cycles, 0.0))
            / jnp.maximum(jnp.sum(keep), 1)
            * (cpu.cpu_ps_per_clk * 1e-3),
        # diagnostics
        n_rd=n_rd, n_wr=n_wr,
        l_ir_final=outs.l_ir[-1],
        chase_lat_ns=ksum(outs.sum_chase_lat_ticks).astype(jnp.float32)
            * (cfg.platform.dram.dram_ps_per_clk * 1e-3)
            / jnp.maximum(ksum(outs.chase_rd), 1).astype(jnp.float32),
        injected=ksum(outs.injected),
        weave_events=weave_events, weave_sat=weave_sat,
        # telemetry planes and command records pass through raw, full
        # (W, ...) per-window series (consumers slice warmup / filter
        # NONE slots themselves — `repro.obs`, `repro.oracle`).
        **{k: v for k, v in (diag or {}).items()
           if k.startswith(("tele_", "cmd_"))},
    )
