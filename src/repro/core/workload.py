"""Mess-style workload generation and request injection (bound phase).

The paper profiles every simulation stage with the Mess benchmark [5]:
N-1 *traffic-generator* cores sweep the used bandwidth (at a controlled
pace and read/write mix) while one *pointer-chase* core measures the
load-to-use latency.  This module generates, per ZSim window, the
candidate memory requests of all cores and injects them into the
per-channel controller queues.

Bound-phase semantics (Sec. 3.3) are preserved exactly: issue cycles
are computed against the *immediate-response* latency (1 CPU cycle in
the DAMOV baseline, PI-controlled after stage 04) — once a request is
handed to the memory simulator its issue time can no longer be
adjusted, which is precisely the decoupling bug the paper analyzes.

Abstractions (documented deviations from the C++ platform, all on the
traffic-generator side only):

* Traffic streams are segmented sequential runs (64 lines) with hashed
  segment placement — the access pattern of Mess's generator loops.
* When a channel queue is full, excess candidates are counted into a
  per-core backlog (pressure is preserved; the skipped generator
  addresses are not replayed — statistically equivalent for streaming
  traffic, and the latency probe is never dropped).
* The stride prefetcher (stage 07) is modeled at the traffic cores:
  degree-8 overfetch past segment boundaries plus next-segment
  misprediction, i.e. extra read traffic that does not serve demands.
  The pointer-chase core has no detectable stride, so — like on real
  hardware — it receives no prefetches.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import addrmap
from repro.core.dram import QueueState
from repro.core.timing import DramParams

N_CORES_PER_SOCKET = 24    # the paper's Skylake socket (Table 1)
#: single-socket geometry aliases (the paper's platform; kept as module
#: constants because the DDR4 validation path is defined on them).
#: Multi-socket geometry is derived per `WorkloadConfig` — see
#: `WorkloadConfig.n_cores` / `.n_traffic` / `.chase_core`.
N_CORES = N_CORES_PER_SOCKET
N_TRAFFIC = 23
CHASE_CORE = 23
CAP_DEMAND = 64            # max demand candidates / core / window
CAP_PF = 16                # max prefetch candidates / core / window
CAND = CAP_DEMAND + CAP_PF
SEGMENT_LINES = 64         # traffic stream segment length
BACKLOG_MAX = 192
CHASE_REGION_BITS = 26     # 4 GB pointer-chase region
#: Per-core outstanding-miss bound (Skylake L2 superqueue).  Makes the
#: traffic generators *closed-loop* like real cores: a core can have at
#: most this many lines in flight, so offered load self-throttles as
#: the memory system saturates (bounding queue delay exactly as finite
#: MSHRs do on hardware) instead of growing without bound.
MSHR_CAP = 24


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    """Bound-phase knobs shared by every frontend.

    ``cache_path_cycles`` / ``noc_*_cycles`` are CPU cycles; ``dram``
    carries the device geometry the injected addresses decode against
    (the DDR4-2666 default or any `repro.core.presets` device).

    ``n_sockets`` selects the frontend geometry: each socket adds
    `N_CORES_PER_SOCKET` cores, all of them traffic generators except
    one shared pointer-chase probe on the last core of the last socket
    (the latency instrument stays a single serialized stream, as in
    Mess).  The per-socket frontend issue capacity is
    ``N_CORES_PER_SOCKET * CAP_DEMAND`` demands per window, so total
    offered bandwidth scales with sockets — this is what lets HBM2e be
    driven past the single-socket ~200 GB/s ceiling.

    ``socket_channels`` picks the channel-ownership model of a
    multi-socket platform:

    * ``"interleaved"`` (default) — both sockets address every channel
      (one shared physical address space, channel-interleaved), the
      common server configuration with NUMA interleaving on.
    * ``"partitioned"``  — each socket owns ``n_channels / n_sockets``
      channels (NUMA-local allocation); a socket's requests are folded
      into its own partition, so cross-socket queue contention is
      structurally impossible.
    """

    mapping: str = "simple"
    prefetch: bool = False
    pf_shift: int = 2          # extra pf traffic = quota >> pf_shift (25%)
    cache_path_cycles: int = 50
    noc_req_cycles: int = 0    # extra request-path NOC cycles (stage 06)
    noc_resp_cycles: int = 0
    dram: DramParams = dataclasses.field(default_factory=DramParams)
    n_sockets: int = 1
    socket_channels: str = "interleaved"   # or "partitioned"

    def __post_init__(self):
        if self.socket_channels not in ("interleaved", "partitioned"):
            raise ValueError(
                f"socket_channels must be 'interleaved' or 'partitioned', "
                f"got {self.socket_channels!r}")
        if self.n_sockets < 1:
            raise ValueError(f"n_sockets must be >= 1, got {self.n_sockets}")

    @property
    def n_cores(self) -> int:
        """Total frontend cores across all sockets."""
        return N_CORES_PER_SOCKET * self.n_sockets

    @property
    def n_traffic(self) -> int:
        """Traffic-generator cores (all but the shared chase probe)."""
        return self.n_cores - 1

    @property
    def chase_core(self) -> int:
        """The shared pointer-chase probe (last core, last socket)."""
        return self.n_cores - 1


class CoreState(NamedTuple):
    seq: jnp.ndarray           # (24,) per-core stream position
    backlog: jnp.ndarray       # (24,) pending ungranted demand
    chase_carry: jnp.ndarray   # leftover CPU cycles of the chase loop


def init_cores(n_cores: int = N_CORES) -> CoreState:
    return CoreState(seq=jnp.zeros((n_cores,), jnp.int32),
                     backlog=jnp.zeros((n_cores,), jnp.int32),
                     chase_carry=jnp.zeros((), jnp.int32))


def littles_law_budget(lat_est_ps, window_ps) -> jnp.ndarray:
    """Per-core per-window demand budget from the MSHR closed loop.

    A core with ``MSHR_CAP`` in-flight lines at observed memory latency
    ``lat_est_ps`` sustains ``MSHR_CAP / lat`` lines per picosecond
    (Little's law) — per window that is ``MSHR_CAP * window / lat``.
    This is the per-window formulation of finite MSHRs: offered load
    self-throttles as latency grows, exactly like real closed-loop
    cores, which bounds queue delay at saturation.
    """
    return jnp.maximum(
        (MSHR_CAP * window_ps / jnp.maximum(lat_est_ps, 1.0)), 1.0
    ).astype(jnp.int32)


def _lcg(x):
    x = x.astype(jnp.uint32)
    return x * jnp.uint32(2654435761) + jnp.uint32(0x9E3779B9)


def _segment_line(core, k):
    """Traffic stream: 64-line sequential segments at hashed bases."""
    seg = (k >> 6).astype(jnp.uint32)
    h = _lcg(seg * jnp.uint32(31) + core.astype(jnp.uint32) * jnp.uint32(97))
    base = (core.astype(jnp.uint32) << 22)
    return base | ((h & jnp.uint32(0xFFFF)) << 6) | (k.astype(jnp.uint32) & 63)


def _chase_line(k):
    h = _lcg(_lcg(k.astype(jnp.uint32)))
    return (jnp.uint32(1) << 31) | (h >> (32 - CHASE_REGION_BITS) << 0)


class Candidates(NamedTuple):
    """(n_cores, CAND) candidate requests for one window."""

    valid: jnp.ndarray
    line: jnp.ndarray          # uint32 cache-line index
    is_write: jnp.ndarray
    issue_cycle: jnp.ndarray   # within-window CPU cycle
    is_chase: jnp.ndarray
    is_pf: jnp.ndarray         # speculative prefetch (not demand)


def chase_probe(seq, carry, l_ir_cycles, cfg: WorkloadConfig,
                window_cycles):
    """Pointer-chase latency probe: one window of serialized loads.

    One outstanding load at a time; in the bound phase the next load
    issues after cache-path + immediate-response cycles (the ZSim
    two-phase semantics the paper corrects).  Shared by every frontend —
    the probe is the platform's latency instrument, independent of the
    workload driving the traffic cores.

    Returns ``(valid, line, issue, iters, new_carry, iter_cycles)``
    where the first three are (CAND,) per-slot arrays.
    """
    j = jnp.arange(CAND, dtype=jnp.int32)
    noc_rt = cfg.noc_req_cycles + cfg.noc_resp_cycles
    iter_cycles = jnp.maximum(
        cfg.cache_path_cycles + noc_rt + l_ir_cycles, 1)
    budget = window_cycles + carry
    iters = jnp.minimum(CAND, budget // iter_cycles)
    new_carry = budget - iters * iter_cycles
    valid = j < iters
    line = _chase_line(seq + j)
    issue = j * iter_cycles
    return valid, line, issue, iters, new_carry, iter_cycles


def generate(cores: CoreState, pace, wr_num, l_ir_cycles,
             cfg: WorkloadConfig, window_cycles: int = 1000,
             budget=CAP_DEMAND):
    """Bound phase: all cores' candidate requests for one window.

    pace:    int32 — demand requests per traffic core per window.
    wr_num:  int32 — write fraction numerator (den=64).
    l_ir_cycles: int32 — current immediate-response latency.
    budget:  int32 — MSHR closed-loop cap (`littles_law_budget`).
    Returns ``(Candidates, aux)``; the aux dict carries the quota /
    backlog / chase bookkeeping that `MessFrontend.update` folds into
    the next window's `CoreState`.
    """
    n_cores = cfg.n_cores
    cid = jnp.arange(n_cores, dtype=jnp.int32)[:, None]       # (N,1)
    j = jnp.arange(CAND, dtype=jnp.int32)[None, :]            # (1,CAND)
    is_traffic = cid < cfg.n_traffic

    # ---- traffic demand ------------------------------------------------
    # Closed loop: per-window demand capped by the MSHR budget.
    want = pace + cores.backlog                                # (24,)
    quota = jnp.minimum(jnp.minimum(CAP_DEMAND, want),
                        budget)[..., None]                     # (24,1)
    k = cores.seq[:, None] + j                                 # (24,CAND)
    t_valid = is_traffic & (j < quota)
    t_line = _segment_line(cid, k)
    # deterministic write interleave at rate wr_num/64
    t_write = ((k + 1) * wr_num) // 64 - (k * wr_num) // 64 > 0
    t_issue = j * window_cycles // jnp.maximum(quota, 1)

    # ---- stride-prefetcher extra traffic (stage 07) ---------------------
    pf_valid = jnp.zeros_like(t_valid)
    if cfg.prefetch:
        pf_quota = jnp.minimum(CAP_PF, quota[..., 0] >> cfg.pf_shift)[:, None]
        jp = j - CAP_DEMAND
        pf_valid = is_traffic & (jp >= 0) & (jp < pf_quota)
        pf_line = _segment_line(cid, cores.seq[:, None] + quota + jp)
        t_valid = t_valid | pf_valid
        t_line = jnp.where(pf_valid, pf_line, t_line)
        t_write = t_write & ~pf_valid
        t_issue = jnp.where(
            pf_valid, jp * window_cycles // jnp.maximum(pf_quota, 1), t_issue)

    # ---- pointer chase (the latency probe) ------------------------------
    cv, c_line, c_issue, chase_iters, chase_carry, iter_cycles = chase_probe(
        cores.seq[cfg.chase_core], cores.chase_carry, l_ir_cycles, cfg,
        window_cycles)
    c_valid = (cid == cfg.chase_core) & cv[None, :]

    cand = Candidates(
        valid=(t_valid & is_traffic) | c_valid,
        line=jnp.where(is_traffic, t_line, c_line),
        is_write=jnp.where(is_traffic, t_write, False),
        issue_cycle=jnp.where(is_traffic, t_issue, c_issue).astype(jnp.int32),
        is_chase=c_valid,
        is_pf=pf_valid & is_traffic,
    )
    aux = dict(quota=quota[..., 0], want=want, chase_iters=chase_iters,
               chase_carry=chase_carry, iter_cycles=iter_cycles)
    return cand, aux


def inject_queue(queue: QueueState, cand: Candidates, clock, w,
                 cfg: WorkloadConfig):
    """Scatter candidates into per-channel queue slots (bounded admit).

    Admission is chase-first then issue-order round-robin.  This is the
    frontend-agnostic half of the CPU->memory interface: any bound-phase
    workload (Mess pace generator, trace replay, ...) produces
    `Candidates` and hands them off here.

    Returns ``(queue', acc_demand, n_accepted)`` where ``acc_demand`` is
    the (n_cores,) per-core count of accepted demand (non-prefetch)
    requests — the frontend uses it to advance its own state.

    Multi-socket channel ownership (``cfg.socket_channels``): with
    ``"partitioned"`` a request's decoded channel is folded into its
    socket's ``n_channels / n_sockets`` partition; ``"interleaved"``
    leaves the decode untouched (all sockets address all channels).
    """
    C, Q = queue.valid.shape
    n_cores = cand.valid.shape[0]
    n = n_cores * CAND
    flat = jax.tree_util.tree_map(lambda a: a.reshape(n), cand)
    core_of = jnp.repeat(jnp.arange(n_cores, dtype=jnp.int32), CAND)

    dec = addrmap.decode(flat.line, cfg.mapping, dram=cfg.dram)
    channel = dec.channel
    if cfg.n_sockets > 1 and cfg.socket_channels == "partitioned":
        if C % cfg.n_sockets:
            raise ValueError(
                f"partitioned ownership needs n_channels ({C}) divisible "
                f"by n_sockets ({cfg.n_sockets})")
        cps = C // cfg.n_sockets
        socket_of = core_of // N_CORES_PER_SOCKET
        channel = socket_of * cps + channel % cps
    ch = jnp.where(flat.valid, channel, C)            # invalid -> ch C
    # admission key: chase first, then issue order, then core id; the
    # core stride must exceed the largest core count (64 covers two
    # sockets) or wrapped ids would re-rank cores across sockets
    key = ((1 - flat.is_chase.astype(jnp.int32)) * (1 << 24)
           + flat.issue_cycle * 64 + core_of)
    order = jnp.argsort(ch * (1 << 26) + key)
    ch_s = ch[order]

    counts = jnp.bincount(ch_s, length=C + 1)
    start = jnp.concatenate([jnp.zeros(1, jnp.int32),
                             jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    r = jnp.arange(n, dtype=jnp.int32) - start[ch_s]  # rank within channel

    # free queue slots, invalid-first
    free_order = jnp.argsort(queue.valid, axis=1, stable=True)  # (C,Q)
    n_free = Q - jnp.sum(queue.valid, axis=1)                   # (C,)
    ch_c = jnp.minimum(ch_s, C - 1)
    accepted = (ch_s < C) & (r < n_free[ch_c])
    slot = jnp.where(accepted,
                     free_order[ch_c, jnp.minimum(r, Q - 1)], Q)  # Q = drop

    # request becomes visible at the MC after the cache+NOC path
    arrival_cycle = (w * clock.window_cycles + flat.issue_cycle[order]
                     + cfg.cache_path_cycles + cfg.noc_req_cycles)
    arrival_tick = clock.cycle_to_tick(arrival_cycle)
    issue_abs = (w * clock.window_cycles + flat.issue_cycle[order])

    def put(qf, val):
        return qf.at[ch_c, slot].set(
            jnp.where(accepted, val, qf[ch_c, jnp.minimum(slot, Q - 1)]),
            mode="drop")

    queue = QueueState(
        valid=put(queue.valid, jnp.ones_like(ch_c)),
        is_write=put(queue.is_write, flat.is_write[order].astype(jnp.int32)),
        arrival=put(queue.arrival, arrival_tick.astype(jnp.int32)),
        issue_cycle=put(queue.issue_cycle, issue_abs.astype(jnp.int32)),
        fbank=put(queue.fbank, dec.flat_bank_for(cfg.dram)[order]),
        row=put(queue.row, dec.row[order]),
        is_chase=put(queue.is_chase, flat.is_chase[order].astype(jnp.int32)),
    )

    acc_demand = jnp.zeros(n_cores, jnp.int32).at[core_of[order]].add(
        (accepted & ~flat.is_pf[order]).astype(jnp.int32))
    return queue, acc_demand, jnp.sum(accepted.astype(jnp.int32))


class MessFrontend:
    """The Mess pace generator as a pluggable bound-phase frontend.

    A *frontend* is the bound-phase half of the platform: it owns a
    per-window state pytree and emits `Candidates` that `inject_queue`
    hands to the memory system.  The protocol (duck-typed; see also
    `repro.traces.frontend.TraceFrontend`):

    * ``init_state()``                    -> state pytree (scan carry)
    * ``bound(state, l_ir_cycles, budget, window_cycles)``
                                          -> (Candidates, aux)
    * ``update(state, aux, acc_demand)``  -> state'  (post-injection)
    * ``progress(state)``                 -> () int32 monotone progress
                                             marker (0 if not meaningful)

    Frontends may close over traced values (`pace` here, trace arrays in
    the replay frontend), so one compiled `run_frontend` program can be
    `vmap`-ed across operating points or applications.
    """

    def __init__(self, pace, wr_num, cfg: WorkloadConfig):
        self.pace = pace
        self.wr_num = wr_num
        self.cfg = cfg

    def init_state(self) -> CoreState:
        return init_cores(self.cfg.n_cores)

    def bound(self, state: CoreState, l_ir_cycles, budget, window_cycles):
        return generate(state, self.pace, self.wr_num, l_ir_cycles,
                        self.cfg, window_cycles, budget)

    def update(self, state: CoreState, aux, acc_demand) -> CoreState:
        cid = jnp.arange(self.cfg.n_cores)
        demanded = jnp.where(cid < self.cfg.n_traffic, aux["want"], 0)
        backlog = jnp.clip(demanded - jnp.minimum(acc_demand, demanded),
                           0, BACKLOG_MAX)
        seq = state.seq + jnp.where(
            cid < self.cfg.n_traffic, aux["quota"],
            aux["chase_iters"]).astype(jnp.int32)
        return CoreState(seq=seq, backlog=backlog,
                         chase_carry=aux["chase_carry"])

    def progress(self, state: CoreState):
        return jnp.zeros((), jnp.int32)
