"""Physical-address -> (channel, rank, bank, row, col) mappings.

The paper (Sec. 4, Fig. 6a) shows that the *simplified* address mapping
shipped with the memory simulators hides the read/write-mix latency
gradient seen on real hardware, and that deploying a complex mapping
reverse-engineered from the actual system (DRAMDig [16]) restores it.

Two mappings are provided, both pure functions over 32-bit cache-line
indices (byte address >> 6), vectorizable under `jax.vmap` and usable
inside `lax.scan`:

* ``simple``      — Ramulator-style RoBaRaCoCh: channel from the lowest
                    line bits, then column, rank, bank, row.  Streams
                    are row-hit friendly and write drains barely disturb
                    open rows.
* ``skylake_xor`` — DRAMDig-flavored XOR-folded mapping: the channel /
                    bank-group / bank bits are XOR hashes that mix row
                    bits in, as reverse-engineered on Skylake.  Streams
                    scatter across banks and write drains collide with
                    reader-open rows, reproducing the measured gradient.

Both mappings are **geometry-parameterized**: pass a `DramParams` (any
`repro.core.presets` device) and the fields are decoded against that
preset's channel/rank/bank/row geometry.  With no ``dram`` argument the
paper's DDR4-2666 geometry is used, bit-for-bit as before.  The
``skylake_xor`` bit positions are only meaningful on the DDR4 geometry
they were reverse-engineered from; on any other preset the request is
served by `decode_xor_fold`, a generic XOR-folded mapping with the same
fidelity-relevant property (fine-grain scatter + row-bit mixing).

Field packing (line index, little endian):  the mapping functions return
int32 fields; `flat_bank` = rank * banks_per_rank + bank is what the
bank-state arrays are indexed by (use `DecodedAddr.flat_bank_for` for
non-default geometries).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.timing import DramParams

LINES_PER_ROW = 128        # 8 KB row / 64 B line
N_BANKS = 16               # banks per rank (4 groups x 4)
N_RANKS = 2
N_CHANNELS = 6


class DecodedAddr(NamedTuple):
    channel: jnp.ndarray   # [0, n_channels)
    rank: jnp.ndarray      # [0, ranks_per_channel)
    bank: jnp.ndarray      # [0, banks_per_rank)
    row: jnp.ndarray       # [0, rows_per_bank)
    col: jnp.ndarray       # [0, lines_per_row) line-within-row

    @property
    def flat_bank(self):
        """``rank * banks_per_rank + bank`` on the DDR4 geometry."""
        return self.rank * N_BANKS + self.bank

    def flat_bank_for(self, dram: DramParams):
        """Geometry-aware bank-state index: rank * banks_per_rank + bank."""
        return self.rank * dram.banks_per_rank + self.bank

    @property
    def bank_group(self):
        return self.bank >> 2


def _bit(x, i):
    return (x >> i) & 1


def decode_simple(line, xp=jnp, dram: DramParams | None = None) -> DecodedAddr:
    """RoBaRaCoCh: ch | col | rank | bank | row  (low -> high bits).

    ``dram`` selects the geometry (channels / ranks / banks / row
    reach); omitted, the DDR4-2666 default applies, unchanged.
    """
    C = dram.n_channels if dram else N_CHANNELS
    R = dram.ranks_per_channel if dram else N_RANKS
    B = dram.banks_per_rank if dram else N_BANKS
    lpr = dram.lines_per_row if dram else LINES_PER_ROW
    row_mask = (dram.rows_per_bank if dram else (1 << 17)) - 1
    line = xp.asarray(line).astype(xp.uint32)
    ch = (line % C).astype(xp.int32)
    a = line // C
    col = (a % lpr).astype(xp.int32)
    a = a // lpr
    rank = (a % R).astype(xp.int32)
    a = a // R
    bank = (a % B).astype(xp.int32)
    row = ((a // B) & row_mask).astype(xp.int32)
    return DecodedAddr(ch, rank, bank, row, col)


def decode_skylake_xor(line, xp=jnp) -> DecodedAddr:
    """DRAMDig-style XOR-folded Skylake mapping.

    Skylake's 6 channels are 2 integrated memory controllers x 3
    channels.  The MC select and the 3-way channel select both hash
    low *and* high (row) bits; bank-group / bank bits XOR row bits in.
    This is the property that matters for fidelity (fine-grain scatter
    + row-bit mixing), with bit positions chosen per DRAMDig's Skylake
    tables.
    """
    line = xp.asarray(line).astype(xp.uint32)
    # memory-controller select: XOR fold of alternating bits
    mc = _bit(line, 0) ^ _bit(line, 6) ^ _bit(line, 11) ^ _bit(line, 17)
    # 3-way channel select: mod-3 of a folded value that includes row bits
    ch3 = ((line >> 1) ^ (line >> 7) ^ (line >> 13) ^ (line >> 19)) % 3
    ch = (mc * 3 + ch3).astype(xp.int32)
    # bank group (2 bits) and bank-in-group (2 bits): XOR with row bits
    bg0 = _bit(line, 2) ^ _bit(line, 12)
    bg1 = _bit(line, 3) ^ _bit(line, 14)
    ba0 = _bit(line, 4) ^ _bit(line, 15)
    ba1 = _bit(line, 5) ^ _bit(line, 16)
    bank = (bg0 | (bg1 << 1) | (ba0 << 2) | (ba1 << 3)).astype(xp.int32)
    rank = (_bit(line, 8) ^ _bit(line, 18)).astype(xp.int32)
    # column: low-ish bits not consumed by the hashes
    col = ((line ^ (line >> 9)) % LINES_PER_ROW).astype(xp.int32)
    row = ((line >> 9) & 0x1FFFF).astype(xp.int32)
    return DecodedAddr(ch, rank, bank, row, col)


def decode_xor_fold(line, dram: DramParams, xp=jnp) -> DecodedAddr:
    """Generic XOR-folded mapping for non-DDR4 geometries.

    Carries the fidelity-relevant properties of the reverse-engineered
    Skylake mapping — channel/bank selects hash low *and* high (row)
    bits so sequential streams scatter fine-grain across channels and
    banks — expressed over an arbitrary `DramParams` geometry instead
    of DRAMDig's fixed DDR4 bit positions.
    """
    C = dram.n_channels
    R = dram.ranks_per_channel
    B = dram.banks_per_rank
    lpr = dram.lines_per_row
    row_mask = dram.rows_per_bank - 1
    line = xp.asarray(line).astype(xp.uint32)
    mix = line ^ (line >> 6) ^ (line >> 12) ^ (line >> 18)
    ch = (mix % C).astype(xp.int32)
    a = line // C
    col = ((a ^ (a >> 9)) % lpr).astype(xp.int32)
    bank = (((a // lpr) ^ (line >> 13)) % B).astype(xp.int32)
    rank = (((line >> 8) ^ (line >> 17)) % R).astype(xp.int32)
    row = ((line >> 9) & row_mask).astype(xp.int32)
    return DecodedAddr(ch, rank, bank, row, col)


def encode_simple(dec: DecodedAddr, dram: DramParams | None = None,
                  xp=np):
    """Inverse of `decode_simple`: pack fields back into a line index.

    Exact for **any** geometry, within the device's capacity:
    ``encode_simple(decode_simple(line)) == line`` for every line
    index below ``channels * lines_per_row * ranks * banks * rows``
    (beyond that `decode_simple` truncates the row field and the line
    is not representable), and ``decode_simple(encode_simple(fields))``
    recovers any in-range fields.  Host-side numpy by default — this
    is the property-test / fuzzer utility, not a simulation path.
    """
    C = dram.n_channels if dram else N_CHANNELS
    R = dram.ranks_per_channel if dram else N_RANKS
    B = dram.banks_per_rank if dram else N_BANKS
    lpr = dram.lines_per_row if dram else LINES_PER_ROW
    row = xp.asarray(dec.row).astype(xp.int64)
    line = ((((row * B + xp.asarray(dec.bank)) * R + xp.asarray(dec.rank))
             * lpr + xp.asarray(dec.col)) * C + xp.asarray(dec.channel))
    return line.astype(xp.uint32)


def xor_fold_encodable(dram: DramParams) -> str | None:
    """Why `encode_xor_fold` cannot invert this geometry (None = it can).

    `decode_xor_fold` is a lossy hash in general; a constructive
    inverse exists only where every decoded field occupies its own bit
    range of the line and each XOR tap lands on already-solved bits:
    power-of-two channel/column/bank/row extents, at most 2 ranks, a
    channel select of <= 6 bits (below the first XOR tap at bit 6),
    and channel+column+bank packed under the rank bit at 8.  No real
    preset qualifies (DDR4/DDR5 have non-power-of-two channel counts;
    HBM2e packs 9 channel+column+bank bits) — the encoder exists for
    the synthetic geometries of the property tests and the fuzzer.
    """
    bits = {}
    for name, n in (("channels", dram.n_channels),
                    ("ranks", dram.ranks_per_channel),
                    ("banks", dram.banks_per_rank),
                    ("lines_per_row", dram.lines_per_row),
                    ("rows_per_bank", dram.rows_per_bank)):
        b = int(n).bit_length() - 1
        if n <= 0 or (1 << b) != n:
            return f"{name}={n} is not a power of two"
        bits[name] = b
    if dram.ranks_per_channel > 2:
        return f"ranks={dram.ranks_per_channel} > 2 (one rank XOR bit)"
    if bits["channels"] > 6:
        return (f"channels={dram.n_channels} needs "
                f"{bits['channels']} > 6 bits (first XOR tap)")
    low = bits["channels"] + bits["lines_per_row"] + bits["banks"]
    if low > 8:
        return (f"channel+column+bank need {low} > 8 bits "
                "(collides with the rank bit)")
    return None


def encode_xor_fold(dec: DecodedAddr, dram: DramParams, xp=np):
    """Inverse of `decode_xor_fold` on encodable geometries.

    Solves the XOR folds field-by-field in dependency order — row bits
    first (they feed every hash), then the rank bit, bank and column
    fields, and the channel fold last — so
    ``decode_xor_fold(encode_xor_fold(fields)) == fields`` whenever
    `xor_fold_encodable` returns ``None`` and the fields are in range.
    Raises `ValueError` (with the reason) on any other geometry.
    """
    reason = xor_fold_encodable(dram)
    if reason is not None:
        raise ValueError(f"geometry not xor_fold-encodable: {reason}")
    C, R = dram.n_channels, dram.ranks_per_channel
    B, lpr = dram.banks_per_rank, dram.lines_per_row
    cb = C.bit_length() - 1
    lb = lpr.bit_length() - 1
    line = xp.asarray(dec.row).astype(xp.int64) << 9
    if R == 2:
        line = line | ((xp.asarray(dec.rank) ^ ((line >> 17) & 1)) << 8)
    line = line | ((xp.asarray(dec.bank) ^ ((line >> 13) % B)) << (cb + lb))
    line = line | ((xp.asarray(dec.col) ^ ((line >> (cb + 9)) % lpr)) << cb)
    line = line | ((xp.asarray(dec.channel)
                    ^ ((line >> 6) ^ (line >> 12) ^ (line >> 18))) % C)
    return line.astype(xp.uint32)


MAPPINGS = {
    "simple": decode_simple,
    "skylake_xor": decode_skylake_xor,
}

_DDR4_GEOMETRY = (N_CHANNELS, N_RANKS, N_BANKS, LINES_PER_ROW, 1 << 17)


def _is_default_geometry(dram: DramParams | None) -> bool:
    return dram is None or (
        dram.n_channels, dram.ranks_per_channel, dram.banks_per_rank,
        dram.lines_per_row, dram.rows_per_bank) == _DDR4_GEOMETRY


def decode(line, mapping: str = "simple", xp=jnp,
           dram: DramParams | None = None) -> DecodedAddr:
    """Decode cache-line indices against a mapping + device geometry.

    Args:
        line: uint32 cache-line indices (byte address >> 6), any shape.
        mapping: ``"simple"`` or ``"skylake_xor"``.
        dram: device geometry; ``None`` means the DDR4-2666 default.
            ``"skylake_xor"`` on a non-DDR4 geometry falls back to the
            generic `decode_xor_fold` (same scatter properties).
    Returns:
        `DecodedAddr` int32 fields, each in its geometry's range.
    """
    if mapping not in MAPPINGS:
        raise ValueError(f"unknown mapping {mapping!r}; "
                         f"one of {sorted(MAPPINGS)}")
    if mapping == "simple":
        return decode_simple(line, xp=xp, dram=dram)
    if _is_default_geometry(dram):
        return decode_skylake_xor(line, xp=xp)
    return decode_xor_fold(line, dram, xp=xp)


def check_fields(dec: DecodedAddr, dram: DramParams | None = None) -> bool:
    """Host-side range validation (used by property tests)."""
    d = dram or DramParams()
    ch = np.asarray(dec.channel)
    return bool(
        (ch >= 0).all() and (ch < d.n_channels).all()
        and (np.asarray(dec.rank) < d.ranks_per_channel).all()
        and (np.asarray(dec.bank) < d.banks_per_rank).all()
        and (np.asarray(dec.row) < d.rows_per_bank).all()
        and (np.asarray(dec.col) < d.lines_per_row).all()
    )
