"""Physical-address -> (channel, rank, bank, row, col) mappings.

The paper (Sec. 4, Fig. 6a) shows that the *simplified* address mapping
shipped with the memory simulators hides the read/write-mix latency
gradient seen on real hardware, and that deploying a complex mapping
reverse-engineered from the actual system (DRAMDig [16]) restores it.

Two mappings are provided, both pure functions over 32-bit cache-line
indices (byte address >> 6), vectorizable under `jax.vmap` and usable
inside `lax.scan`:

* ``simple``      — Ramulator-style RoBaRaCoCh: channel from the lowest
                    line bits, then column, rank, bank, row.  Streams
                    are row-hit friendly and write drains barely disturb
                    open rows.
* ``skylake_xor`` — DRAMDig-flavored XOR-folded mapping: the channel /
                    bank-group / bank bits are XOR hashes that mix row
                    bits in, as reverse-engineered on Skylake.  Streams
                    scatter across banks and write drains collide with
                    reader-open rows, reproducing the measured gradient.

Field packing (line index, little endian):  the mapping functions return
int32 fields; `flat_bank` = rank * banks_per_rank + bank is what the
bank-state arrays are indexed by.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.timing import DramParams

LINES_PER_ROW = 128        # 8 KB row / 64 B line
N_BANKS = 16               # banks per rank (4 groups x 4)
N_RANKS = 2
N_CHANNELS = 6


class DecodedAddr(NamedTuple):
    channel: jnp.ndarray   # [0, 6)
    rank: jnp.ndarray      # [0, 2)
    bank: jnp.ndarray      # [0, 16)  (bank-group folded: bg = bank >> 2)
    row: jnp.ndarray       # [0, 2^17)
    col: jnp.ndarray       # [0, 128) line-within-row

    @property
    def flat_bank(self):
        return self.rank * N_BANKS + self.bank

    @property
    def bank_group(self):
        return self.bank >> 2


def _bit(x, i):
    return (x >> i) & 1


def decode_simple(line, xp=jnp) -> DecodedAddr:
    """RoBaRaCoCh: ch | col | rank | bank | row  (low -> high bits)."""
    line = xp.asarray(line).astype(xp.uint32)
    ch = (line % N_CHANNELS).astype(xp.int32)
    a = line // N_CHANNELS
    col = (a % LINES_PER_ROW).astype(xp.int32)
    a = a // LINES_PER_ROW
    rank = (a % N_RANKS).astype(xp.int32)
    a = a // N_RANKS
    bank = (a % N_BANKS).astype(xp.int32)
    row = ((a // N_BANKS) & 0x1FFFF).astype(xp.int32)
    return DecodedAddr(ch, rank, bank, row, col)


def decode_skylake_xor(line, xp=jnp) -> DecodedAddr:
    """DRAMDig-style XOR-folded Skylake mapping.

    Skylake's 6 channels are 2 integrated memory controllers x 3
    channels.  The MC select and the 3-way channel select both hash
    low *and* high (row) bits; bank-group / bank bits XOR row bits in.
    This is the property that matters for fidelity (fine-grain scatter
    + row-bit mixing), with bit positions chosen per DRAMDig's Skylake
    tables.
    """
    line = xp.asarray(line).astype(xp.uint32)
    # memory-controller select: XOR fold of alternating bits
    mc = _bit(line, 0) ^ _bit(line, 6) ^ _bit(line, 11) ^ _bit(line, 17)
    # 3-way channel select: mod-3 of a folded value that includes row bits
    ch3 = ((line >> 1) ^ (line >> 7) ^ (line >> 13) ^ (line >> 19)) % 3
    ch = (mc * 3 + ch3).astype(xp.int32)
    # bank group (2 bits) and bank-in-group (2 bits): XOR with row bits
    bg0 = _bit(line, 2) ^ _bit(line, 12)
    bg1 = _bit(line, 3) ^ _bit(line, 14)
    ba0 = _bit(line, 4) ^ _bit(line, 15)
    ba1 = _bit(line, 5) ^ _bit(line, 16)
    bank = (bg0 | (bg1 << 1) | (ba0 << 2) | (ba1 << 3)).astype(xp.int32)
    rank = (_bit(line, 8) ^ _bit(line, 18)).astype(xp.int32)
    # column: low-ish bits not consumed by the hashes
    col = ((line ^ (line >> 9)) % LINES_PER_ROW).astype(xp.int32)
    row = ((line >> 9) & 0x1FFFF).astype(xp.int32)
    return DecodedAddr(ch, rank, bank, row, col)


MAPPINGS = {
    "simple": decode_simple,
    "skylake_xor": decode_skylake_xor,
}


def decode(line, mapping: str = "simple", xp=jnp) -> DecodedAddr:
    try:
        fn = MAPPINGS[mapping]
    except KeyError:
        raise ValueError(f"unknown mapping {mapping!r}; "
                         f"one of {sorted(MAPPINGS)}") from None
    return fn(line, xp=xp)


def check_fields(dec: DecodedAddr, dram: DramParams | None = None) -> bool:
    """Host-side range validation (used by property tests)."""
    d = dram or DramParams()
    ch = np.asarray(dec.channel)
    return bool(
        (ch >= 0).all() and (ch < d.n_channels).all()
        and (np.asarray(dec.rank) < d.ranks_per_channel).all()
        and (np.asarray(dec.bank) < d.banks_per_rank).all()
        and (np.asarray(dec.row) < d.rows_per_bank).all()
        and (np.asarray(dec.col) < LINES_PER_ROW).all()
    )
