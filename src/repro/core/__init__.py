"""The paper's contribution: three-view memory-simulation methodology.

Public API:

* `StageConfig`, `run_point` — the integrated ZSim-style platform.
* `run_frontend`              — same platform under any bound-phase
                                frontend (Mess pace or trace replay).
* `STAGES`, `get_stage`       — the artifact's stage progression.
* `PRESETS`, `get_preset`, `stage_for` — DDR4/DDR5/HBM2e device
                                presets (`repro.core.presets`).
* `sweep`                     — Mess bandwidth-latency characterization.
* `make_policy`               — Ramulator/Ramulator2/DRAMsim3 flavors.
* `reference`                 — per-preset real-system ground-truth
                                curves (measured-anchor families).
"""
from repro.core.backends import BACKENDS, make_policy
from repro.core.mess import SweepResult, sweep
from repro.core.platform import StageConfig, run_frontend, run_point
from repro.core.presets import (PRESET_ORDER, PRESETS, get_preset,
                                platform_for, stage_for)
from repro.core.stages import STAGES, STAGE_ORDER, get_stage

__all__ = [
    "BACKENDS", "make_policy", "SweepResult", "sweep",
    "StageConfig", "run_frontend", "run_point",
    "STAGES", "STAGE_ORDER", "get_stage",
    "PRESETS", "PRESET_ORDER", "get_preset", "platform_for", "stage_for",
]
