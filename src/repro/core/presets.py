"""Memory-device preset registry: DDR4-2666, DDR5-4800, HBM2e.

The paper validates one platform (Skylake + DDR4-2666).  "Cleaning up
the Mess" shows that fidelity results do **not** transfer across device
generations without re-validation, and the Mess methodology is defined
per memory technology as a family of bandwidth-latency curves — so the
reproduction carries one `DramParams` instance per technology, each
with its own reference curves (`repro.core.reference`) and per-app
runtime anchors (`repro.traces.anchors`).

Presets (geometry / clock / protocol deltas):

* ``ddr4_2666`` — the paper's platform: 6 channels x 2 ranks x 16
  banks (4 bank groups), 64-bit bus, tCK = 750 ps, all-bank refresh.
  This is byte-identical to ``DramParams()`` so every PR-1 result is
  unchanged.
* ``ddr5_4800`` — 6 DIMMs as **12 independent 32-bit sub-channels**
  (JEDEC DDR5 splits each DIMM in two), 2 ranks x 32 banks (8 bank
  groups x 4), BL16 (8 bus cycles / 64 B line), tCK ~ 417 ps, and
  **same-bank refresh** (REFsb: one bank per rank blocked for tRFCsb,
  rotating, instead of the whole rank for tRFC).  The tCCD_L/tCCD_S
  split widens to 16/8 per JEDEC DDR5-4800.
* ``hbm2e`` — one 8-channel HBM2e stack as **16 pseudo-channels**
  (8 x 2), 1 rank x 16 banks (4 bank groups), 64-bit pseudo-channel
  bus, narrow BL8 bursts (4 bus cycles), tCK = 625 ps, many-channel /
  low-per-channel-bandwidth geometry.

All timing fields are bus cycles of the preset's own tCK (see
`DramParams`); tCK values are integer picoseconds because the paper's
picosecond clocking (Listing 1b) advances integer ps counters — the
0.08% rounding of DDR5's 416.67 ps to 417 ps is documented here and
absorbed by the preset's reference anchors.

The CPU side of the platform (24-core Skylake socket) is held fixed
across presets: the sweep isolates the *memory device*, not the core.
The number of **sockets** is a `StageConfig` knob, not a preset
property: ``stage_for("04-model-correct", "hbm2e", n_sockets=2)``
doubles the frontend issue capacity (47 traffic cores), which is what
HBM2e needs to be driven past the single-socket ~200 GB/s ceiling
(docs/VALIDATION.md documents the measured effect).
"""
from __future__ import annotations

from repro.core.timing import CpuParams, DramParams, PlatformParams

#: The paper's device — identical to ``DramParams()`` (asserted in tests).
DDR4_2666 = DramParams()

#: JEDEC DDR5-4800B (40-39-39), 16 Gb devices, modeled per sub-channel.
DDR5_4800 = DramParams(
    n_channels=12,            # 6 DIMMs x 2 independent sub-channels
    ranks_per_channel=2,
    banks_per_rank=32,        # 8 bank groups x 4 banks
    bank_groups=8,
    rows_per_bank=1 << 16,
    cols_per_row=512,         # 4 KB row per sub-channel (64 lines)
    bus_bytes=4,              # 32-bit sub-channel
    dram_ps_per_clk=417,      # 416.67 ps rounded (documented above)
    mt_per_s=4800,
    same_bank_refresh=True,
    tCL=40, tRCD=39, tRP=39, tRAS=76,
    tBL=8,                    # BL16 on the 32-bit bus -> 64 B line
    tCCD_S=8, tCCD_L=16,      # JEDEC DDR5 split (8 tCK / max(8tCK, 5ns))
    tWR=72,                   # 30 ns
    tWTR_S=12, tWTR_L=24,     # 5 / 10 ns
    tRTP=18,                  # 7.5 ns
    tRRD_S=8, tRRD_L=12,
    tFAW=32,
    tCWL=38,
    tRTRS=2,
    tREFI=292,                # REFsb cadence: 3.9 us / 32 banks ~ 122 ns
    tRFC=312,                 # tRFCsb = 130 ns (16 Gb)
)

#: One HBM2e stack at 3.2 Gbps/pin, modeled per pseudo-channel.
HBM2E = DramParams(
    n_channels=16,            # 8 legacy channels x 2 pseudo-channels
    ranks_per_channel=1,
    banks_per_rank=16,        # 4 bank groups x 4 banks
    bank_groups=4,
    rows_per_bank=1 << 16,
    cols_per_row=256,         # 2 KB row per pseudo-channel (32 lines)
    bus_bytes=8,              # 64-bit pseudo-channel
    dram_ps_per_clk=625,      # 1.6 GHz clock, 3.2 GT/s
    mt_per_s=3200,
    same_bank_refresh=False,
    tCL=23, tRCD=23, tRP=23, tRAS=53,   # ~14.3 / 14.3 / 14.3 / 33 ns
    tBL=4,                    # BL8 on the 64-bit bus -> 64 B line
    tCCD_S=2, tCCD_L=4,
    tWR=26,                   # 16 ns
    tWTR_S=6, tWTR_L=13,
    tRTP=6,
    tRRD_S=6, tRRD_L=7,
    tFAW=26,                  # 16 ns
    tCWL=7,
    tRTRS=0,                  # single rank: no rank switch
    tREFI=6240,               # 3.9 us
    tRFC=416,                 # 260 ns
)

PRESETS: dict[str, DramParams] = {
    "ddr4_2666": DDR4_2666,
    "ddr5_4800": DDR5_4800,
    "hbm2e": HBM2E,
}

PRESET_ORDER = tuple(PRESETS)


def get_preset(name: str) -> DramParams:
    """Fetch a device preset by name.

    Args:
        name: one of ``"ddr4_2666"``, ``"ddr5_4800"``, ``"hbm2e"``.
    Returns:
        The frozen `DramParams` instance (shared, not a copy).
    """
    try:
        return PRESETS[name]
    except KeyError:
        raise ValueError(
            f"unknown device preset {name!r}; one of {list(PRESETS)}"
        ) from None


def platform_for(preset: str, cpu: CpuParams | None = None) -> PlatformParams:
    """The paper's Skylake CPU frontend attached to a device preset."""
    return PlatformParams(cpu=cpu or CpuParams(), dram=get_preset(preset))


def stage_for(stage: str, preset: str = "ddr4_2666", **overrides):
    """A `StageConfig` of ``stage`` running on device ``preset``.

    Thin alias of ``get_stage(stage, preset=preset, **overrides)``;
    ``stage_for(s, "ddr4_2666")`` is exactly ``get_stage(s)`` — the
    default platform *is* the DDR4 preset.
    """
    from repro.core.stages import get_stage

    return get_stage(stage, preset=preset, **overrides)


def weave_budgets(preset: str) -> dict:
    """Per-clock-mode weave scan lengths of one device preset.

    The event-horizon weave engine replaces the dense
    one-step-per-DRAM-tick scan with a static *event budget* derived
    from bus occupancy (`repro.core.clocking.event_budget`); the
    budget is a device property as much as a clock one — burst length
    (tBL), refresh cadence, and tick period all enter.  Returns
    ``{clock_mode: (ticks_per_window, events_per_window)}`` — e.g. the
    DDR4 picosecond model scans 635 ticks dense vs 199 events
    (3.2x fewer steps), DDR5-4800's BL16 bursts push the ratio past
    5x.  Used by benchmarks and docs to report the per-preset step
    reduction.
    """
    from repro.core.clocking import CLOCK_MODES, make_clock

    plat = platform_for(preset)
    out = {}
    for mode in CLOCK_MODES:
        clock = make_clock(mode, plat)
        out[mode] = (clock.ticks_per_window_static,
                     clock.events_per_window_static)
    return out
