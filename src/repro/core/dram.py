"""Cycle-accurate DRAM device + memory-controller model (the weave backend).

A JAX-native reimplementation of the Ramulator-class cycle-accurate
memory simulation used in the paper: per-bank state machines with the
full DDRx timing set (tRCD/tRP/tCL/tRAS/tCCD_S/L/tWTR/tRTP/tRRD/tFAW/
tREFI/tRFC), FR-FCFS scheduling with open-page policy, watermark-based
write draining, rank-aware bus turnaround, and per-rank (all-bank) or
rotating per-bank (DDR5 REFsb) refresh.  The device geometry and
timings come from a `DramParams` instance — DDR4-2666 by default, or
any preset from `repro.core.presets` (DDR5-4800, HBM2e); nothing in
this module assumes a fixed channel/rank/bank-group count.

Everything is vectorized over (channel, queue-slot) and
(channel, rank*bank) so one simulated memory tick is a fixed dataflow
graph usable inside ``jax.lax.scan`` (and batchable with ``jax.vmap``
across sweep points).  Dynamic structures of the C++ simulators map to
static shapes:

* request queues  -> fixed-capacity slot arrays with a `valid` mask,
* FR-FCFS         -> masked argmax over a priority score
                     (row-hit >> activate >> precharge, oldest first),
* FAW sliding window -> a 4-deep shift register of ACT timestamps.

The same tick step has a Pallas TPU kernel twin
(`repro.kernels.bank_timing`) for the eligibility+select hot loop; this
module is the pure-jnp reference semantics (`ref.py` delegates here).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.timing import DramParams

# command codes (REF never competes in the FR-FCFS select — refresh is
# deadline-driven inside `tick` — but the command-stream recorder and
# the `repro.oracle` legality checker use it as a first-class code)
NONE, RD, WR, ACT, PRE, REF = 0, 1, 2, 3, 4, 5

_BIG = jnp.int32(1 << 28)

#: log2 latency-histogram buckets: bucket ``b`` counts values in
#: ``[2^b, 2^(b+1))``; 24 buckets cover 1 DRAM tick .. 16.7M ps
#: (values past the top edge clip into the last bucket).
N_HIST = 24


class BankPlanes(NamedTuple):
    """Loop-invariant index planes of one device geometry.

    These are pure functions of `DramParams` (never of simulation
    state), so they are built **once** per device — host-side numpy, so
    they embed as XLA constants — instead of being re-derived with
    ``jnp.arange`` on every `tick` / `next_event` trace.  Both weave
    engines (the dense per-tick scan and the event-horizon scan) share
    one instance via `bank_planes`.
    """

    cidx: np.ndarray          # (C,)  channel index
    rank_of: np.ndarray       # (RB,) rank of each flat bank
    grp_of: np.ndarray        # (RB,) bank group of each flat bank
    bank_in_rank: np.ndarray  # (RB,) bank index within its rank


@functools.lru_cache(maxsize=None)
def bank_planes(dram: DramParams) -> BankPlanes:
    """The precomputed `BankPlanes` of one device (cached per preset)."""
    C = dram.n_channels
    RB = dram.banks_per_channel
    nbanks = dram.banks_per_rank
    bank = np.arange(RB, dtype=np.int32)
    return BankPlanes(
        cidx=np.arange(C, dtype=np.int32),
        rank_of=bank // nbanks,
        grp_of=(bank % nbanks) // dram.banks_per_group,
        bank_in_rank=bank % nbanks,
    )


@dataclasses.dataclass(frozen=True)
class SchedulerPolicy:
    """Backend-flavor knobs (Ramulator / Ramulator2 / DRAMsim3)."""

    name: str = "ramulator"
    # Per-channel request-slot array.  Slots double as the *staging
    # buffer* for requests issued later in the window (entries are
    # invisible to the scheduler until their `arrival` tick), so the
    # depth must cover a full window of offered traffic — 23 cores x
    # 64 req / 6 channels ~ 245 — or injection artificially caps the
    # achieved bandwidth far below the DRAM service rate.
    queue_depth: int = 256
    drain_hi: int = 20             # write-drain high watermark
    drain_lo: int = 6              # write-drain low watermark
    row_hit_cap: int = 0           # 0 = pure FR-FCFS; >0 caps hit streaks
    mc_extra_ticks: int = 0        # stage-10 delay buffer (MC pipe + PHY)


class QueueState(NamedTuple):
    """Per-channel request queue; all fields (C, Q) int32."""

    valid: jnp.ndarray
    is_write: jnp.ndarray
    arrival: jnp.ndarray       # DRAM tick at which the request is visible
    issue_cycle: jnp.ndarray   # CPU cycle at which the core issued it
    fbank: jnp.ndarray         # rank*16 + bank
    row: jnp.ndarray
    is_chase: jnp.ndarray      # pointer-chase (latency-probe) request


class BankState(NamedTuple):
    """Per-bank / per-channel controller state; all times in DRAM ticks.

    ``C`` = channels, ``R`` = ranks/channel, ``RB`` = ranks x banks.
    """

    open_row: jnp.ndarray      # (C, RB) int32, -1 = precharged
    next_act: jnp.ndarray      # (C, RB) earliest tick for ACT
    next_rd: jnp.ndarray       # (C, RB)
    next_wr: jnp.ndarray       # (C, RB)
    next_pre: jnp.ndarray      # (C, RB)
    faw: jnp.ndarray           # (C, R, 4) last four ACT ticks, oldest first
    next_ref: jnp.ndarray      # (C, R) next refresh deadline
    ref_slot: jnp.ndarray      # (C, R) rotating REFsb bank index (DDR5)
    bus_free: jnp.ndarray      # (C,) data-bus free tick
    wtr_until: jnp.ndarray     # (C,) reads blocked until (write->read turn)
    rtw_until: jnp.ndarray     # (C,) writes blocked until (read->write turn)
    last_rank: jnp.ndarray     # (C,) rank of last data burst (tRTRS)
    drain: jnp.ndarray         # (C,) bool: write-drain mode
    hit_streak: jnp.ndarray    # (C,) consecutive row-hit grants (for cap)


class TickStats(NamedTuple):
    """One tick's completion statistics, **per channel** ``(C,)``.

    Latency units differ by view on purpose: ``sum_rd_lat_ticks`` is
    DRAM ticks (view ① — multiply by ``dram_ps_per_clk`` for time),
    ``sum_if_lat_ps`` is CPU-perceived picoseconds (view ② — already
    crossed the clock domain).

    The fields are per-channel vectors (a channel issues at most one
    command per tick) and are *accumulated in time order per channel*
    by the weave loops.  That makes the float32 ``sum_if_lat_ps``
    window total bit-identical between the dense and event engines:
    idle ticks contribute exact ``+0.0`` (the float32 identity), so
    both engines fold the same non-zero values in the same order.
    """

    served_rd: jnp.ndarray         # (C,) int32
    served_wr: jnp.ndarray
    sum_rd_lat_ticks: jnp.ndarray  # simulator view: completion - arrival
    sum_if_lat_ps: jnp.ndarray     # interface view (CPU-domain), float32
    chase_rd: jnp.ndarray
    sum_chase_lat_ticks: jnp.ndarray


def zero_stats(dram: DramParams) -> TickStats:
    """A zeroed per-channel `TickStats` accumulator."""
    zi = jnp.zeros((dram.n_channels,), jnp.int32)
    return TickStats(served_rd=zi, served_wr=zi, sum_rd_lat_ticks=zi,
                     sum_if_lat_ps=jnp.zeros((dram.n_channels,),
                                             jnp.float32),
                     chase_rd=zi, sum_chase_lat_ticks=zi)


class TickTele(NamedTuple):
    """One tick's telemetry increments (the simulator-view counter
    planes of ``repro.obs``), **per channel** ``(C,)`` unless noted.

    Everything here is an *event count* or an *event-accounted time
    integral* — never a per-tick state sample — so the planes
    accumulate to identical window totals under the dense and the
    event-horizon weave engines (the event engine evaluates exactly
    the ticks where these events can occur).

    Row-locality counters are derivable from the command mix by the
    classical identity (each request retires with exactly one CAS):
    ``hits = cas - act``, ``misses = act - pre``, ``conflicts = pre``
    — see `repro.obs.telemetry.summarize` (refresh-forced re-ACTs can
    make per-window ``hits`` dip negative; the reduction clamps and
    documents this).
    """

    n_act: jnp.ndarray             # ACT commands issued
    n_pre: jnp.ndarray             # PRE commands issued
    n_cas_rd: jnp.ndarray          # read CAS (== TickStats.served_rd)
    n_cas_wr: jnp.ndarray          # write CAS
    n_ref: jnp.ndarray             # refresh events (per rank deadline)
    drain_enter: jnp.ndarray       # write-drain service bursts entered
    drain_ticks: jnp.ndarray       # drain service dwell (burst spans)
    busy_ticks: jnp.ndarray        # (C, RB) row-open time, at row close
    hist_rd_ticks: jnp.ndarray     # (C, N_HIST) read latency, DRAM ticks
    hist_if_ps: jnp.ndarray        # (C, N_HIST) CPU-perceived read ps


class TeleState(NamedTuple):
    """Telemetry-only carry state (exists only with telemetry on).

    Time integrals are accounted at *grant* events so both weave
    engines agree exactly: ``opened_at`` remembers each bank's last
    ACT tick (busy time is added when the row closes via PRE or
    refresh); ``last_wr_t`` / ``wr_burst`` track the channel's current
    write-CAS burst (drain dwell accrues at each write grant).
    """

    opened_at: jnp.ndarray         # (C, RB) int32 tick of last ACT
    last_wr_t: jnp.ndarray         # (C,) int32 tick of last write CAS
    wr_burst: jnp.ndarray          # (C,) bool: last CAS was a write


def zero_tele(dram: DramParams) -> TickTele:
    """A zeroed per-channel `TickTele` accumulator."""
    C, RB = dram.n_channels, dram.banks_per_channel
    zc = jnp.zeros((C,), jnp.int32)
    zh = jnp.zeros((C, N_HIST), jnp.int32)
    return TickTele(n_act=zc, n_pre=zc, n_cas_rd=zc, n_cas_wr=zc,
                    n_ref=zc, drain_enter=zc, drain_ticks=zc,
                    busy_ticks=jnp.zeros((C, RB), jnp.int32),
                    hist_rd_ticks=zh, hist_if_ps=zh)


def init_tele(dram: DramParams) -> TeleState:
    """Fresh telemetry carry (all banks closed, no drain in progress)."""
    C, RB = dram.n_channels, dram.banks_per_channel
    return TeleState(opened_at=jnp.zeros((C, RB), jnp.int32),
                     last_wr_t=jnp.zeros((C,), jnp.int32),
                     wr_burst=jnp.zeros((C,), bool))


class TickCmd(NamedTuple):
    """One tick's granted-command record (`StageConfig.cmd_trace`).

    The raw material of the `repro.oracle` command stream: what each
    channel's controller *did* at the evaluated tick.  Everything is
    derived from the tick's own command-select intermediates, so with
    the flag off the traced graph is untouched — and because command
    grants and refresh firings happen at identical ticks under both
    weave engines (the bit-identity the golden grid proves), filtering
    the records down to ``cmd != NONE`` / ``ref`` rows yields the
    **same per-channel stream** from either engine.

    Fields (``C`` channels, ``R`` ranks/channel):

    * ``cmd`` ``(C,)`` — `NONE`/`RD`/`WR`/`ACT`/`PRE` granted this tick
      (refresh is recorded separately; it can coincide with a grant).
    * ``t`` ``(C,)`` — the evaluated DRAM tick (absolute).
    * ``fbank`` ``(C,)`` — flat bank (``rank * banks_per_rank + bank``)
      of the granted command; meaningful only when ``cmd != NONE``.
    * ``row`` ``(C,)`` — target row for ACT/RD/WR; ``-1`` for PRE
      (the open row is being closed) and idle ticks.
    * ``ref`` ``(C, R)`` bool — rank ``r`` hit its refresh deadline.
    * ``ref_bank`` ``(C, R)`` — the REFsb bank-in-rank refreshed
      (pre-rotation `BankState.ref_slot`); ``-1`` for all-bank refresh.
    """

    cmd: jnp.ndarray
    t: jnp.ndarray
    fbank: jnp.ndarray
    row: jnp.ndarray
    ref: jnp.ndarray
    ref_bank: jnp.ndarray


def log2_bucket(v) -> jnp.ndarray:
    """``floor(log2(max(v, 1)))`` clipped to ``[0, N_HIST - 1]``.

    Integer-exact (count-leading-zeros, no float log), so histogram
    bucket edges land exactly on powers of two.
    """
    v = jnp.maximum(jnp.asarray(v, jnp.int32), 1)
    return jnp.minimum(31 - jax.lax.clz(v), N_HIST - 1)


def init_queue(dram: DramParams, policy: SchedulerPolicy,
               n_sockets: int = 1) -> QueueState:
    """Empty per-channel request queue: (C, queue_depth) int32 slots.

    ``queue_depth`` is derived from one socket's per-window offered
    traffic (see `SchedulerPolicy`); ``n_sockets`` scales the staging
    capacity so a multi-socket frontend keeps the same invariant —
    without it a two-socket ddr4 run (47 cores x 64 req / 6 channels
    ~ 501/window) would overflow the staging slots and silently drop
    replayed demand.
    """
    C, Q = dram.n_channels, policy.queue_depth * n_sockets
    z = jnp.zeros((C, Q), jnp.int32)
    return QueueState(valid=z, is_write=z, arrival=z, issue_cycle=z,
                      fbank=z, row=z - 1, is_chase=z)


def init_banks(dram: DramParams) -> BankState:
    """All banks precharged, refresh deadlines staggered across ranks.

    Also builds (and caches) the device's `BankPlanes` — the
    loop-invariant index planes both weave engines gather against.
    """
    bank_planes(dram)            # warm the per-device plane cache
    C = dram.n_channels
    RB = dram.banks_per_channel
    R = dram.ranks_per_channel
    zi = jnp.zeros((C, RB), jnp.int32)
    return BankState(
        open_row=zi - 1,
        next_act=zi, next_rd=zi, next_wr=zi, next_pre=zi,
        faw=jnp.full((C, R, 4), -(1 << 20), jnp.int32),
        # stagger refresh deadlines across ranks like real controllers
        next_ref=(dram.tREFI
                  + jnp.arange(R, dtype=jnp.int32)[None, :] * (dram.tREFI // R)
                  + jnp.zeros((C, R), jnp.int32)),
        ref_slot=jnp.zeros((C, R), jnp.int32),
        bus_free=jnp.zeros((C,), jnp.int32),
        wtr_until=jnp.zeros((C,), jnp.int32),
        rtw_until=jnp.zeros((C,), jnp.int32),
        last_rank=jnp.zeros((C,), jnp.int32),
        drain=jnp.zeros((C,), bool),
        hit_streak=jnp.zeros((C,), jnp.int32),
    )


def _gather(bank_field, fbank):
    """(C, RB) field gathered per queue entry -> (C, Q)."""
    return jnp.take_along_axis(bank_field, fbank, axis=1)


def tick(queue: QueueState, banks: BankState, t, *,
         dram: DramParams, policy: SchedulerPolicy,
         tick2cpu_num: int, tick2cpu_den: int, cpu_ps_per_clk: int,
         active=True, planes: BankPlanes | None = None,
         telemetry: bool = False, tele: TeleState | None = None,
         cmd_trace: bool = False):
    """Advance the memory system by one DRAM tick.

    Args:
        queue, banks: current `QueueState` / `BankState`.
        t: current DRAM tick (int32, traced) — a scalar, or a
            per-channel ``(C,)`` vector (channels are fully decoupled
            inside a window, which is what lets the event-horizon
            engine advance each channel along its own event times).
        dram, policy: static device timings + controller flavor.
        tick2cpu_num, tick2cpu_den: DRAM tick -> CPU-perceived
            picoseconds under the active clock model
            (``cpu_ps = tick * num // den``).
        cpu_ps_per_clk: CPU picoseconds per CPU cycle (476 for 2.1 GHz).
        active: gates windows whose static tick budget exceeds the
            clock model's exact tick count (inactive ticks are no-ops);
            scalar or per-channel ``(C,)``, like ``t``.
        planes: the device's precomputed `BankPlanes`; defaults to the
            cached `bank_planes(dram)`.
        telemetry: **static** flag; when False (default) the traced
            computation is exactly the historical tick graph.  When
            True, the tick additionally returns its `TickTele`
            increments and the threaded `TeleState`.
        tele: the telemetry carry (`TeleState`); only read with
            ``telemetry=True``.
        cmd_trace: **static** flag; when True the tick additionally
            returns its `TickCmd` command record (the `repro.oracle`
            recorder).  Like ``telemetry``, the False path traces
            exactly the historical graph.

    Returns:
        ``(queue', banks', TickStats)``; ``telemetry=True`` appends
        ``(TickTele, TeleState)`` and ``cmd_trace=True`` appends a
        trailing `TickCmd` (the flags compose, in that order).
        Latencies in `TickStats` are DRAM ticks (simulator view) and
        picoseconds (interface view).
    """
    C = dram.n_channels
    nbanks = dram.banks_per_rank
    if planes is None:
        planes = bank_planes(dram)
    cidx = planes.cidx
    t = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (C,))
    active = jnp.broadcast_to(jnp.asarray(active), (C,))
    t_r = t[:, None]                    # against (C, R) / (C, RB) / (C, Q)
    open_row_pre = banks.open_row       # pre-refresh (telemetry: busy)
    ref_slot_pre = banks.ref_slot       # pre-rotation (cmd_trace: REFsb)

    # ---- refresh ----------------------------------------------------
    # All-bank (DDR4/HBM2e): close the whole rank, block it for tRFC.
    # Same-bank (DDR5 REFsb): block only the rotating target bank for
    # tRFCsb; the rest of the rank keeps serving.
    ref_due = active[:, None] & (t_r >= banks.next_ref)         # (C, R)
    refmask = jnp.repeat(ref_due, nbanks, axis=1)               # (C, RB)
    if dram.same_bank_refresh:
        target = jnp.repeat(banks.ref_slot, nbanks, axis=1)     # (C, RB)
        refmask = refmask & (planes.bank_in_rank[None, :] == target)
        ref_slot = jnp.where(ref_due, (banks.ref_slot + 1) % nbanks,
                             banks.ref_slot)
    else:
        ref_slot = banks.ref_slot
    open_row = jnp.where(refmask, -1, banks.open_row)
    next_act = jnp.where(refmask,
                         jnp.maximum(banks.next_act, t_r + dram.tRFC),
                         banks.next_act)
    next_ref = jnp.where(ref_due, banks.next_ref + dram.tREFI, banks.next_ref)
    banks = banks._replace(open_row=open_row, next_act=next_act,
                           next_ref=next_ref, ref_slot=ref_slot)

    # ---- write-drain hysteresis --------------------------------------
    arrived = (queue.valid == 1) & (queue.arrival <= t_r)       # (C, Q)
    nw = jnp.sum(arrived & (queue.is_write == 1), axis=1)       # (C,)
    nr = jnp.sum(arrived & (queue.is_write == 0), axis=1)
    drain = jnp.where(banks.drain, nw > policy.drain_lo, nw >= policy.drain_hi)
    drain = drain | ((nr == 0) & (nw > 0))
    banks = banks._replace(drain=drain)

    # ---- per-entry eligibility ---------------------------------------
    open_e = _gather(banks.open_row, queue.fbank)
    nact_e = _gather(banks.next_act, queue.fbank)
    nrd_e = _gather(banks.next_rd, queue.fbank)
    nwr_e = _gather(banks.next_wr, queue.fbank)
    npre_e = _gather(banks.next_pre, queue.fbank)
    rank_e = queue.fbank // nbanks                              # (C, Q)

    row_hit = open_e == queue.row
    closed = open_e < 0
    is_wr = queue.is_write == 1
    bus_ok = (t >= banks.bus_free)[:, None]
    faw_ok_rank = t_r >= banks.faw[:, :, 0] + dram.tFAW         # (C, R)
    faw_ok_e = jnp.take_along_axis(faw_ok_rank, rank_e, axis=1)
    drain_c = drain[:, None]

    # During a drain the channel is dedicated to writes; outside it,
    # to reads (standard watermark write-buffering).
    side_ok = jnp.where(is_wr, drain_c, ~drain_c)
    elig_rd = (arrived & ~is_wr & row_hit & (t_r >= nrd_e) & bus_ok
               & (t >= banks.wtr_until)[:, None] & ~drain_c)
    elig_wr = (arrived & is_wr & row_hit & (t_r >= nwr_e) & bus_ok
               & (t >= banks.rtw_until)[:, None] & drain_c)
    elig_act = arrived & closed & (t_r >= nact_e) & faw_ok_e & side_ok

    # FR-FCFS guard: don't precharge a row that still has pending hits
    # *on the active side* — during a write drain only write hits count
    # (a pending read hit must not block the drain's precharges, or the
    # drain can never finish and the channel deadlocks).
    hit_pend = jnp.zeros(
        (C, dram.banks_per_channel), bool).at[cidx[:, None], queue.fbank].max(
        arrived & row_hit & (is_wr == drain_c))
    hit_pend_e = _gather(hit_pend, queue.fbank)
    elig_pre = (arrived & ~closed & ~row_hit & (t_r >= npre_e)
                & ~hit_pend_e & side_ok)

    # ---- FR-FCFS priority: CAS > ACT > PRE, oldest-first --------------
    age = _BIG - queue.arrival
    score = jnp.where(elig_rd | elig_wr, 3 * _BIG + age,
             jnp.where(elig_act, 2 * _BIG + age,
              jnp.where(elig_pre, 1 * _BIG + age, 0)))
    if policy.row_hit_cap > 0:
        # Ramulator2-style starvation cap: after `cap` consecutive CAS
        # grants, age wins over row-hit priority.
        capped = (banks.hit_streak >= policy.row_hit_cap)[:, None]
        score = jnp.where(capped & (elig_rd | elig_wr), 1 * _BIG + age, score)
        score = jnp.where(capped & elig_act, 3 * _BIG + age, score)
    score = jnp.where(active[:, None], score, 0)

    sel = jnp.argmax(score, axis=1)                             # (C,)
    sel_score = jnp.take_along_axis(score, sel[:, None], 1)[:, 0]
    any_cmd = sel_score > 0

    def pick(field):
        return jnp.take_along_axis(field, sel[:, None], 1)[:, 0]

    s_fb = pick(queue.fbank)
    s_row = pick(queue.row)
    s_arr = pick(queue.arrival)
    s_issue = pick(queue.issue_cycle)
    s_rank = s_fb // nbanks
    s_bg = (s_fb % nbanks) // dram.banks_per_group
    s_iswr = pick(is_wr.astype(jnp.int32)) == 1
    s_chase = pick(queue.is_chase) == 1
    s_rd_ok = pick(elig_rd.astype(jnp.int32)) == 1
    s_wr_ok = pick(elig_wr.astype(jnp.int32)) == 1
    s_act_ok = pick(elig_act.astype(jnp.int32)) == 1
    s_pre_ok = pick(elig_pre.astype(jnp.int32)) == 1
    if policy.row_hit_cap > 0:
        capped1 = banks.hit_streak >= policy.row_hit_cap
        # under the cap inversion an ACT can outrank CAS; recompute cmd
        s_cas = any_cmd & (s_rd_ok | s_wr_ok) & ~(capped1 & s_act_ok)
        s_act = any_cmd & s_act_ok & ~s_cas
    else:
        s_cas = any_cmd & (s_rd_ok | s_wr_ok)
        s_act = any_cmd & s_act_ok & ~s_cas
    s_pre = any_cmd & s_pre_ok & ~s_cas & ~s_act
    s_rd = s_cas & ~s_iswr
    s_wr = s_cas & s_iswr

    # ---- apply the selected command per channel ----------------------
    bsel = (cidx, s_fb)

    # ACT
    same_rank = planes.rank_of[None, :] == s_rank[:, None]
    same_grp = (planes.grp_of[None, :] == s_bg[:, None]) & same_rank
    open_row = banks.open_row.at[bsel].set(
        jnp.where(s_act, s_row, banks.open_row[bsel]))
    nact = jnp.where(s_act[:, None] & same_rank,
                     jnp.maximum(banks.next_act, t_r + dram.tRRD_S),
                     banks.next_act)
    nact = jnp.where(s_act[:, None] & same_grp,
                     jnp.maximum(nact, t_r + dram.tRRD_L), nact)
    nact = nact.at[bsel].set(
        jnp.where(s_act, jnp.maximum(nact[bsel], t + dram.tRC), nact[bsel]))
    nrd = banks.next_rd.at[bsel].set(
        jnp.where(s_act, t + dram.tRCD, banks.next_rd[bsel]))
    nwr = banks.next_wr.at[bsel].set(
        jnp.where(s_act, t + dram.tRCD, banks.next_wr[bsel]))
    npre = banks.next_pre.at[bsel].set(
        jnp.where(s_act, t + dram.tRAS, banks.next_pre[bsel]))
    # FAW shift-register push
    faw_new = jnp.concatenate(
        [banks.faw[:, :, 1:],
         jnp.broadcast_to(t[:, None, None], banks.faw[:, :, :1].shape)],
        axis=2)
    act_rank = jax.nn.one_hot(s_rank, dram.ranks_per_channel,
                              dtype=bool) & s_act[:, None]
    faw = jnp.where(act_rank[:, :, None], faw_new, banks.faw)

    # CAS (RD/WR): bus + tCCD (bank-group aware, channel-wide) + turnaround
    rank_switch = s_rank != banks.last_rank
    burst = dram.tBL + jnp.where(rank_switch, dram.tRTRS, 0)
    bus_free = jnp.where(s_cas, t + burst, banks.bus_free)
    last_rank = jnp.where(s_cas, s_rank, banks.last_rank)
    ccd = jnp.where(same_grp, dram.tCCD_L, dram.tCCD_S)
    nrd = jnp.where(s_cas[:, None], jnp.maximum(nrd, t_r + ccd), nrd)
    nwr = jnp.where(s_cas[:, None], jnp.maximum(nwr, t_r + ccd), nwr)
    npre = npre.at[bsel].set(jnp.where(
        s_rd, jnp.maximum(npre[bsel], t + dram.tRTP),
        jnp.where(s_wr, jnp.maximum(npre[bsel],
                                    t + dram.tCWL + dram.tBL + dram.tWR),
                  npre[bsel])))
    wtr_until = jnp.where(s_wr, t + dram.tCWL + dram.tBL + dram.tWTR_L,
                          banks.wtr_until)
    rtw_until = jnp.where(s_rd, t + dram.tCL + dram.tBL + dram.tRTRS
                          - dram.tCWL, banks.rtw_until)

    # PRE
    open_row = open_row.at[bsel].set(
        jnp.where(s_pre, -1, open_row[bsel]))
    nact = nact.at[bsel].set(
        jnp.where(s_pre, jnp.maximum(nact[bsel], t + dram.tRP), nact[bsel]))

    hit_streak = jnp.where(s_cas, banks.hit_streak + 1,
                           jnp.where(any_cmd, 0, banks.hit_streak))

    banks = BankState(open_row=open_row, next_act=nact, next_rd=nrd,
                      next_wr=nwr, next_pre=npre, faw=faw, next_ref=next_ref,
                      ref_slot=ref_slot, bus_free=bus_free,
                      wtr_until=wtr_until, rtw_until=rtw_until,
                      last_rank=last_rank, drain=drain,
                      hit_streak=hit_streak)

    # retire CAS'd entries
    served = jnp.zeros_like(queue.valid).at[cidx, sel].set(
        s_cas.astype(jnp.int32))
    queue = queue._replace(valid=queue.valid & (1 - served))

    # ---- stats --------------------------------------------------------
    done_t = t + dram.tCL + dram.tBL + policy.mc_extra_ticks
    rd_lat = done_t - s_arr                                     # ticks
    if_lat_i = (done_t * tick2cpu_num // tick2cpu_den
                - s_issue * cpu_ps_per_clk)                     # ps, int32
    if_lat_ps = if_lat_i.astype(jnp.float32)
    stats = TickStats(
        served_rd=s_rd.astype(jnp.int32),
        served_wr=s_wr.astype(jnp.int32),
        sum_rd_lat_ticks=jnp.where(s_rd, rd_lat, 0),
        sum_if_lat_ps=jnp.where(s_rd, if_lat_ps, 0.0),
        chase_rd=(s_rd & s_chase).astype(jnp.int32),
        sum_chase_lat_ticks=jnp.where(s_rd & s_chase, rd_lat, 0),
    )
    if not telemetry and not cmd_trace:
        return queue, banks, stats

    extras = ()
    if telemetry:
        # ---- telemetry counter planes (static flag: the path above is
        # the untouched historical graph when telemetry is off) --------
        # Everything is accounted at *events* (command grants, refresh
        # deadlines, row closes), never sampled per tick, so the planes
        # are engine-invariant: the event-horizon scan evaluates
        # exactly the ticks where these events occur.
        if tele is None:
            tele = init_tele(dram)
        # row-open busy time, accounted when the row closes.  A refresh
        # close covers every refreshed bank that held an open row; a
        # PRE close covers the selected bank (ACT and PRE are mutually
        # exclusive per channel per tick, so `opened_at` ordering is
        # safe).
        busy = jnp.where(refmask & (open_row_pre >= 0),
                         t_r - tele.opened_at, 0)
        opened_at = tele.opened_at.at[bsel].set(
            jnp.where(s_act, t, tele.opened_at[bsel]))
        busy = busy.at[bsel].add(jnp.where(s_pre, t - opened_at[bsel], 0))
        # write-drain planes at CAS resolution: a maximal run of write
        # CAS grants (uninterrupted by a read CAS) is one drain service
        # burst, and its dwell — span from first to last write grant,
        # plus one burst of bus time — accrues incrementally at each
        # write grant.  The controller's drain *flag* can flip at ticks
        # the event engine provably need not evaluate (when the last
        # drained write retires, nothing new becomes eligible until the
        # next arrival), so flag transitions are NOT engine-invariant;
        # CAS grants are, by bit-identity of the engines.
        enter = s_wr & ~tele.wr_burst
        dwell = jnp.where(s_wr, jnp.where(tele.wr_burst,
                                          t - tele.last_wr_t, dram.tBL), 0)
        last_wr_t = jnp.where(s_wr, t, tele.last_wr_t)
        wr_burst = jnp.where(s_cas, s_wr, tele.wr_burst)
        # log2 latency histograms: simulator view in DRAM ticks,
        # interface view in CPU-perceived picoseconds (the int behind
        # sum_if_lat_ps)
        one_rd = s_rd.astype(jnp.int32)
        hist_rd = jnp.zeros((C, N_HIST), jnp.int32).at[
            cidx, log2_bucket(rd_lat)].add(one_rd)
        hist_if = jnp.zeros((C, N_HIST), jnp.int32).at[
            cidx, log2_bucket(if_lat_i)].add(one_rd)
        tele_inc = TickTele(
            n_act=s_act.astype(jnp.int32), n_pre=s_pre.astype(jnp.int32),
            n_cas_rd=one_rd, n_cas_wr=s_wr.astype(jnp.int32),
            n_ref=jnp.sum(ref_due.astype(jnp.int32), axis=1),
            drain_enter=enter.astype(jnp.int32), drain_ticks=dwell,
            busy_ticks=busy, hist_rd_ticks=hist_rd, hist_if_ps=hist_if)
        extras = (tele_inc, TeleState(opened_at, last_wr_t, wr_burst))
    if cmd_trace:
        # ---- command-stream record (the `repro.oracle` recorder) -----
        # Pure functions of the command-select intermediates above: the
        # grant code, its bank/row target, and the refresh firings —
        # everything the protocol-legality checker replays.
        cmd = jnp.where(s_rd, RD, jnp.where(s_wr, WR,
                        jnp.where(s_act, ACT,
                                  jnp.where(s_pre, PRE, NONE))))
        cmdrec = TickCmd(
            cmd=cmd.astype(jnp.int32), t=t, fbank=s_fb,
            row=jnp.where(s_act | s_cas, s_row, -1),
            ref=ref_due,
            ref_bank=(jnp.where(ref_due, ref_slot_pre, -1)
                      if dram.same_bank_refresh
                      else jnp.full_like(ref_slot_pre, -1)))
        extras += (cmdrec,)
    return (queue, banks, stats) + extras


def next_event(queue: QueueState, banks: BankState, t, end, *,
               dram: DramParams, policy: SchedulerPolicy,
               planes: BankPlanes | None = None):
    """The exact event horizon: earliest tick > ``t`` where `tick` can act.

    Evaluated on the *post-tick* state at ``t``, this returns — **per
    channel** — the smallest tick at which that channel's behaviour can
    differ from a no-op; the event-driven weave engine jumps each
    channel straight there (`tick` couples channels only through the
    window-level stats reduction, never through state, so per-channel
    time vectors are exact).  Every dense tick strictly between
    ``t[c]`` and the returned tick is provably a no-op for channel
    ``c``: no request arrives, no refresh deadline passes, no command
    becomes issuable, and the write-drain hysteresis sits at its fixed
    point.  The candidates, all exact (never early, never late):

    * **arrival** — the min ``arrival`` over valid not-yet-visible
      entries (visibility changes the drain counts and FR-FCFS pool);
    * **drain settle** — ``t + 1`` whenever one application of the
      write-drain hysteresis would flip the channel's ``drain`` flag
      (the dense scan re-evaluates it every tick; between arrivals and
      retirements one application reaches the fixed point, so a single
      forced step is exact);
    * **CAS** — per arrived row-hit entry on the active drain side:
      ``max(next_rd|next_wr, bus_free, wtr_until|rtw_until)``;
    * **ACT** — per arrived closed-bank entry on the active side:
      ``max(next_act, FAW expiry of its rank)``;
    * **PRE** — per arrived row-conflict entry on the active side with
      no pending same-side row hits: ``next_pre``;
    * **refresh** — the channel's min ``next_ref`` deadline.

    Scheduling *priority* (FR-FCFS score, row-hit caps) never needs a
    candidate: it picks among issuable commands but cannot create one.

    Args:
        queue, banks: post-`tick` state at ``t``.
        t: the tick just evaluated — scalar or per-channel ``(C,)``
            (int32, traced).
        end: static scan horizon (``window start + ticks_per_window``);
            results are clamped into ``[t + 1, end]`` — ``end`` means
            "no event on this channel before the horizon".
    dram, policy: static device timings + controller flavor.
        planes: the device's precomputed `BankPlanes`; defaults to the
            cached `bank_planes(dram)`.

    Returns:
        ``(C,)`` int32 per-channel next-event ticks in ``[t + 1, end]``.
    """
    nbanks = dram.banks_per_rank
    if planes is None:
        planes = bank_planes(dram)
    cidx = planes.cidx
    t = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (dram.n_channels,))
    t_r = t[:, None]

    valid = queue.valid == 1
    arrived = valid & (queue.arrival <= t_r)                    # (C, Q)
    is_wr = queue.is_write == 1

    # ---- candidate: next request arrival ------------------------------
    pending = valid & (queue.arrival > t_r)
    ev = jnp.min(jnp.where(pending, queue.arrival, _BIG), axis=1)

    # ---- the drain side the scheduler holds until the next event ------
    # One application of the hysteresis reaches its fixed point under a
    # frozen arrived set (see `tick`); eligibility below must use that
    # settled side, and if settling changes the stored flag the dense
    # scan acts on it at t+1 — force a step there.
    nw = jnp.sum(arrived & is_wr, axis=1)                       # (C,)
    nr = jnp.sum(arrived & ~is_wr, axis=1)
    drain = jnp.where(banks.drain, nw > policy.drain_lo,
                      nw >= policy.drain_hi)
    drain = drain | ((nr == 0) & (nw > 0))
    ev = jnp.minimum(ev, jnp.where(drain != banks.drain, t + 1, _BIG))
    drain_c = drain[:, None]

    # ---- per-entry command readiness ----------------------------------
    open_e = _gather(banks.open_row, queue.fbank)
    rank_e = queue.fbank // nbanks
    row_hit = open_e == queue.row
    closed = open_e < 0
    side_ok = jnp.where(is_wr, drain_c, ~drain_c)

    # CAS: bank CAS timer + shared bus + write/read turnaround
    cas_ready = jnp.where(
        is_wr,
        jnp.maximum(_gather(banks.next_wr, queue.fbank),
                    banks.rtw_until[:, None]),
        jnp.maximum(_gather(banks.next_rd, queue.fbank),
                    banks.wtr_until[:, None]))
    cas_ready = jnp.maximum(cas_ready, banks.bus_free[:, None])
    ev = jnp.minimum(ev, jnp.min(jnp.where(
        arrived & row_hit & side_ok, cas_ready, _BIG), axis=1))

    # ACT: bank ACT timer + the rank's FAW sliding-window expiry
    faw_ready = banks.faw[:, :, 0] + dram.tFAW                  # (C, R)
    act_ready = jnp.maximum(_gather(banks.next_act, queue.fbank),
                            jnp.take_along_axis(faw_ready, rank_e, axis=1))
    ev = jnp.minimum(ev, jnp.min(jnp.where(
        arrived & closed & side_ok, act_ready, _BIG), axis=1))

    # PRE: row conflict with no pending same-side hits on the bank
    hit_pend = jnp.zeros(
        (dram.n_channels, dram.banks_per_channel),
        bool).at[cidx[:, None], queue.fbank].max(
        arrived & row_hit & (is_wr == drain_c))
    elig_pre = (arrived & ~closed & ~row_hit & side_ok
                & ~_gather(hit_pend, queue.fbank))
    ev = jnp.minimum(ev, jnp.min(jnp.where(
        elig_pre, _gather(banks.next_pre, queue.fbank), _BIG), axis=1))

    # ---- candidate: refresh deadlines ---------------------------------
    ev = jnp.minimum(ev, jnp.min(banks.next_ref, axis=1))

    return jnp.clip(ev, t + 1, end)
