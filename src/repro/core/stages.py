"""The artifact's experiment-stage progression as first-class configs.

Each stage reproduces one refinement step of the paper and maps to one
or more figures (Artifact Appendix: `00-damov-native` .. `09-...`,
plus our beyond-paper stage 10).  A stage is simply a fully-specified
`StageConfig`; stages differ only in their knobs, never in code —
mirroring the artifact's "same sources, different sb.cfg" design.

| stage               | figure | delta vs previous                       |
|---------------------|--------|-----------------------------------------|
| 00-damov-native     | Fig. 2 | alias of 01 (DAMOV release state)       |
| 01-baseline         | Fig. 2 | broken clock scaling, L_ir = 1 cycle    |
| 02-clock-scale      | Fig. 3 | clock scaling on, integer freqRatio     |
| 03-ps-clock         | Fig. 4 | picosecond clocking (Listing 1b)        |
| 04-model-correct    | Fig. 5 | + PI-controlled immediate response      |
| 05-addrmap          | Fig. 6a| + Skylake XOR address mapping           |
| 06-noc              | Fig. 6b| + 2-D mesh NOC model                    |
| 07-prefetch         | Fig. 6c| + stride prefetchers (full paper stack) |
| 08-dramsim3         | Fig. 7 | full stack on the DRAMsim3 flavor       |
| 09-ramulator2       | Fig. 7 | full stack on the Ramulator 2 flavor    |
| 10-delay-buffer     | Sec. 5 | beyond-paper: + MC-pipeline/PHY delay   |
"""
from __future__ import annotations

import dataclasses

from repro.core.backends import make_policy
from repro.core.platform import StageConfig

_FULL = dict(clock_mode="picosecond", pi_latency=True,
             mapping="skylake_xor", noc="mesh", prefetch=True)

STAGES: dict[str, StageConfig] = {
    "00-damov-native": StageConfig(name="00-damov-native"),
    "01-baseline": StageConfig(name="01-baseline"),
    "02-clock-scale": StageConfig(
        name="02-clock-scale", clock_mode="damov_ceil"),
    "03-ps-clock": StageConfig(
        name="03-ps-clock", clock_mode="picosecond"),
    "04-model-correct": StageConfig(
        name="04-model-correct", clock_mode="picosecond", pi_latency=True),
    "05-addrmap": StageConfig(
        name="05-addrmap", clock_mode="picosecond", pi_latency=True,
        mapping="skylake_xor"),
    "06-noc": StageConfig(
        name="06-noc", clock_mode="picosecond", pi_latency=True,
        mapping="skylake_xor", noc="mesh"),
    "07-prefetch": StageConfig(name="07-prefetch", **_FULL),
    "08-dramsim3": StageConfig(
        name="08-dramsim3", policy=make_policy("dramsim3"), **_FULL),
    "09-ramulator2": StageConfig(
        name="09-ramulator2", policy=make_policy("ramulator2"), **_FULL),
    "10-delay-buffer": StageConfig(
        name="10-delay-buffer",
        policy=make_policy("ramulator", delay_buffer=True), **_FULL),
}

STAGE_ORDER = tuple(STAGES)


def get_stage(name: str, preset: str | None = None,
              **overrides) -> StageConfig:
    """Fetch a stage config, optionally overriding run-length knobs.

    Args:
        name: stage id (``"01-baseline"`` .. ``"10-delay-buffer"``).
        preset: optional memory-device preset (`repro.core.presets`);
            swaps the platform's `DramParams` while keeping the Skylake
            CPU frontend.  ``None`` / ``"ddr4_2666"`` keep the paper's
            device exactly.
        **overrides: any `StageConfig` field (``windows=32, warmup=8``;
            ``telemetry=True`` turns on the three-perspective
            telemetry planes of `repro.obs`; ``cmd_trace=True`` turns
            on the DRAM command-stream recorder of `repro.oracle`).
    """
    try:
        cfg = STAGES[name]
    except KeyError:
        raise ValueError(
            f"unknown stage {name!r}; one of {list(STAGES)}") from None
    if preset is not None and preset != "ddr4_2666":
        from repro.core.presets import get_preset
        plat = overrides.get("platform", cfg.platform)
        overrides["platform"] = dataclasses.replace(
            plat, dram=get_preset(preset))
    return dataclasses.replace(cfg, **overrides) if overrides else cfg
