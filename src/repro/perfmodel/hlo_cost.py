"""Trip-count-aware cost model over post-optimization HLO text.

``compiled.cost_analysis()`` reports while-loop bodies ONCE — a
scan-over-layers train step is undercounted ~n_layers x.  This module
re-derives FLOPs / HBM bytes / collective bytes from the partitioned
HLO with loop trip counts applied:

* computations are split into blocks; ``while`` ops are matched to
  their body computations and trip counts (the loop-bound constant in
  the condition computation);
* scales nest: a scan inside a grad-accumulation scan multiplies;
* FLOPs: 2 x output_elements x contraction_size per ``dot`` (operand
  shapes resolved through a global name->type map);
* HBM bytes: for every *materializing* op (fusion, dot, copy,
  reduce, scatter/gather, dynamic slicing, convert, transpose,
  custom-call) output bytes + operand bytes — post-fusion HLO is
  fusion-level, so this approximates actual HBM traffic;
* collective bytes: as in `hlo.py`, per category.

Only ENTRY and while-body computations are walked (fusion bodies are
counted at their callsites).  All numbers are per-device (the
partitioned module is the per-device program).
"""
from __future__ import annotations

import re

from repro.perfmodel.hlo import COLLECTIVES, DTYPE_BYTES

_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{")
_OP_RE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_ARR_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_WHILE_RE = re.compile(
    r"while\(.*?\), condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_COLL_RE = re.compile(
    r"(" + "|".join(COLLECTIVES) + r")(-start)?\(")

#: ops whose inputs/outputs hit HBM (post-fusion granularity)
_MATERIALIZING = (
    "fusion(", "dot(", "copy(", "reduce(", "reduce-window(",
    "scatter(", "gather(", "dynamic-slice(", "dynamic-update-slice(",
    "convert(", "transpose(", "custom-call(", "select-and-scatter(",
    "broadcast(", "iota(", "concatenate(", "slice(", "pad(", "reverse(",
    "reshape(", "sort(", "rng(", "cholesky(", "triangular-solve(",
)
_SKIP_BYTES = ("bitcast(", "tuple(", "get-tuple-element(", "parameter(",
               "constant(", "after-all(", "partition-id(")


def _split_blocks(text: str):
    blocks, cur, name = {}, None, None
    for line in text.splitlines():
        m = _HEADER_RE.match(line)
        if m:
            name = m.group(2)
            if m.group(1):          # ENTRY
                name = "__entry__"
            cur = []
            blocks[name] = cur
        elif cur is not None:
            cur.append(line)
    return blocks


def _first_array_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _ARR_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str):
    """dims of the FIRST array in a type string (dot outputs are arrays)."""
    m = _ARR_RE.search(type_str)
    if not m:
        return None, []
    dt, dims = m.group(1), m.group(2)
    return dt, [int(d) for d in dims.split(",")] if dims else []


_TYPE_RE = re.compile(
    r"^(\([^()]*\)|\w+\[[\d,]*\](?:\{[^}]*\})?)")


def _leading_type(rest: str) -> str:
    m = _TYPE_RE.match(rest)
    return m.group(1) if m else ""


def _build_type_map(text: str) -> dict:
    types = {}
    for line in text.splitlines():
        m = _OP_RE.match(line)
        if m:
            types[m.group(1)] = _leading_type(m.group(2))
        else:
            # computation params: "name: f32[...]," inside headers
            for pm in re.finditer(r"%?([\w.\-]+):\s*(\w+\[[\d,]*\])", line):
                types.setdefault(pm.group(1), pm.group(2))
    return types


def _dot_flops(line: str, types: dict) -> float:
    out_m = re.search(r"=\s*(\w+\[[\d,]*\])", line)
    if not out_m:
        return 0.0
    _, out_dims = _shape_dims(out_m.group(1))
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    # contraction size from the lhs operand; newer XLA prints operand
    # types inline — "dot(f32[..]{..} %lhs, ...)" — older prints bare
    # "%lhs".  Accept both, preferring the inline type.
    ops_m = re.search(
        r"dot\(\s*(?:(\w+\[[\d,]*\])(?:\{[^}]*\})?\s+)?%?([\w.\-]+),",
        line)
    cd_m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    if not ops_m or not cd_m:
        return 2.0 * out_elems        # fallback
    lhs_t = ops_m.group(1) or types.get(ops_m.group(2), "")
    _, lhs_dims = _shape_dims(lhs_t)
    contract = 1
    for i in cd_m.group(1).split(","):
        if i and int(i) < len(lhs_dims):
            contract *= lhs_dims[int(i)]
    return 2.0 * out_elems * contract


def _fusion_param_charges(body_lines: list, types: dict) -> dict:
    """Per-parameter byte charges for a fusion computation.

    A parameter consumed ONLY by a dynamic-slice (scan slicing stacked
    layer weights, fused into the loop body) is charged at the slice
    size, not the full stacked array — otherwise every scanned-weights
    cell is overcharged by ~n_layers x.
    Returns {param_index: bytes or None (= charge full size)}.
    """
    param_of = {}
    for ln in body_lines:
        pm = re.match(r"\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*.*?"
                      r"parameter\((\d+)\)", ln)
        if pm:
            param_of[pm.group(1)] = int(pm.group(2))
    charges = {}
    for name, idx in param_of.items():
        uses = []
        for ln in body_lines:
            if f"%{name}" in ln and f"%{name} =" not in ln \
                    and f"%{name}," not in ln.split("=")[0]:
                uses.append(ln)
        if len(uses) == 1 and ("dynamic-slice(" in uses[0]
                               or " slice(" in uses[0]):
            om = _OP_RE.match(uses[0])
            if om:
                charges[idx] = _first_array_bytes(
                    _leading_type(om.group(2)))
    return charges


def _line_bytes(line: str, types: dict, blocks: dict | None = None) -> int:
    """Output + operand bytes of one materializing op line."""
    m = _OP_RE.match(line)
    if not m:
        return 0
    rest = m.group(2)
    if any(s in rest for s in _SKIP_BYTES):
        return 0
    if not any(s in rest for s in _MATERIALIZING):
        return 0
    out_b = _first_array_bytes(_leading_type(rest))
    # Slice-family ops move only the slice, not the operand: a scan
    # slicing stacked layer weights reads L x less than the operand
    # size (counting operands here inflated memory terms ~100x).
    if "dynamic-slice(" in rest or " gather(" in rest \
            or " slice(" in rest:
        return 2 * out_b                       # read slice + write out
    if "dynamic-update-slice(" in rest or " scatter(" in rest:
        # traffic ~ read+write of the update region (operand 1/2)
        am = re.search(r"[\w\-]+\((.*?)\)(,|$| )", rest)
        refs = re.findall(r"%([\w.\-]+)", am.group(1)) if am else []
        upd = refs[1] if len(refs) > 1 else None
        upd_b = _first_array_bytes(types.get(upd, "")) if upd else 0
        return 2 * upd_b
    # operands: %refs inside the op's (...) argument list
    am = re.search(r"[\w\-]+\((.*?)\)(,|$| )", rest)
    in_b = 0
    if am:
        refs = re.findall(r"%([\w.\-]+)", am.group(1))
        charges = {}
        if blocks is not None and "fusion(" in rest:
            cm_ = re.search(r"calls=%?([\w.\-]+)", rest)
            if cm_ and cm_.group(1) in blocks:
                charges = _fusion_param_charges(blocks[cm_.group(1)],
                                                types)
        for i, ref in enumerate(refs):
            if i in charges:
                in_b += charges[i]
            else:
                in_b += _first_array_bytes(types.get(ref, ""))
    return out_b + in_b


def analyze(text: str) -> dict:
    """Trip-scaled per-device flops / bytes / collective bytes."""
    blocks = _split_blocks(text)
    types = _build_type_map(text)

    # while graph: parent computation -> [(body, cond, trip)]
    body_info = {}          # body -> (parent, trip)
    for parent, lines in blocks.items():
        for line in lines:
            m = _WHILE_RE.search(line)
            if not m:
                continue
            cond, body = m.group(1), m.group(2)
            trip = 1
            for cl in blocks.get(cond, []):
                cm = _CONST_RE.search(cl)
                if cm:
                    trip = max(trip, int(cm.group(1)))
            body_info[body] = (parent, trip)

    def scale_of(comp: str, _depth=0) -> int:
        if comp == "__entry__" or _depth > 16:
            return 1
        if comp in body_info:
            parent, trip = body_info[comp]
            return trip * scale_of(parent, _depth + 1)
        return 1  # not entry/while-body: handled at callsite

    walk = ["__entry__"] + list(body_info)
    flops = 0.0
    byts = 0.0
    coll = {c: 0 for c in COLLECTIVES}
    coll_counts = {c: 0 for c in COLLECTIVES}
    for comp in walk:
        sc = scale_of(comp)
        for line in blocks.get(comp, []):
            if " dot(" in line:
                flops += _dot_flops(line, types) * sc
            cb = _COLL_RE.search(line)
            if cb and not cb.group(2) == "-done":
                out_m = re.search(r"=\s*(\([^=]*?\)|[\w\[\],{} ]+?)\s*"
                                  + cb.group(1), line)
                if out_m:
                    coll[cb.group(1)] += _first_array_bytes(
                        out_m.group(1)) * sc
                    coll_counts[cb.group(1)] += 1
            byts += _line_bytes(line, types, blocks) * sc
    return dict(flops=flops, bytes=byts,
                bytes_by_op=coll, counts=coll_counts,
                total_bytes=sum(coll.values()))
