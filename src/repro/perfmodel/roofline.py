"""Three-term roofline from the compiled dry-run artifact.

TPU v5e hardware constants (per chip):
    compute   197 TFLOP/s bf16
    HBM       819 GB/s
    ICI       ~50 GB/s per link

Terms (seconds, per step, per chip — the partitioned module IS the
per-chip program):

    compute    = HLO_FLOPs_dev / 197e12
    memory     = HLO_bytes_dev / 819e9
    collective = collective_bytes_dev / 50e9

plus MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N_active for
MoE, and the usefulness ratio MODEL_FLOPS / (HLO_FLOPs_dev × chips).
"""
from __future__ import annotations

import dataclasses

import jax

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s / link


@dataclasses.dataclass(frozen=True)
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_dev: float
    hlo_bytes_dev: float
    collective_bytes_dev: float
    model_flops: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    useful_ratio: float
    bytes_per_device: float        # peak memory from memory_analysis

    def as_dict(self):
        return dataclasses.asdict(self)


def make(arch: str, shape: str, mesh: str, chips: int, *,
         cost: dict, collectives: dict, model_flops: float,
         bytes_per_device: float) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = float(collectives["total_bytes"])
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = coll / ICI_BW
    terms = dict(compute=compute_s, memory=memory_s,
                 collective=collective_s)
    bottleneck = max(terms, key=terms.get)
    denom = flops * chips
    return Roofline(
        arch=arch, shape=shape, mesh=mesh, chips=chips,
        hlo_flops_dev=flops, hlo_bytes_dev=byts,
        collective_bytes_dev=coll, model_flops=model_flops,
        compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, bottleneck=bottleneck,
        useful_ratio=(model_flops / denom) if denom else 0.0,
        bytes_per_device=bytes_per_device)


# ---------------------------------------------------------------------------
# MODEL_FLOPS


def count_params_struct(struct_tree) -> int:
    return sum(int(x.size) if hasattr(x, "size") else 0
               for x in jax.tree_util.tree_leaves(struct_tree))


def count_active_params(struct_tree, top_k: int, n_experts: int) -> int:
    """MoE-aware: expert tensors (key starts with 'we_') count k/E."""
    total = 0
    flat = jax.tree_util.tree_flatten_with_path(struct_tree)[0]
    for path, leaf in flat:
        size = int(leaf.size)
        keyname = str(path[-1])
        if "we_" in keyname and n_experts > 0:
            size = size * top_k // n_experts
        total += size
    return total


def model_flops(kind: str, n_active: int, tokens: int) -> float:
    """6·N·D for training, 2·N·D for forward/decode."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active * tokens
