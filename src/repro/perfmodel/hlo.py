"""HLO-text analysis: collective bytes per category.

`compiled.cost_analysis()` has no collective accounting, so we parse
the post-partitioning HLO: every ``all-gather`` / ``all-reduce`` /
``reduce-scatter`` / ``all-to-all`` / ``collective-permute`` op's
output bytes are summed per category.  Async pairs are counted once
(the ``-start`` op carries the shape; ``-done`` is skipped).

The numbers are *per-device* bytes (the partitioned module is the
per-device program), which is what the roofline's collective term
wants: per-chip collective bytes / per-chip link bandwidth.
"""
from __future__ import annotations

import re

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter",
               "all-to-all", "collective-permute")

_OP_RE = re.compile(
    r"=\s*(?P<shape>\([^=]*?\)|[\w\[\],{}:#\s]*?)\s*"
    r"(?P<op>" + "|".join(COLLECTIVES) + r")(?P<suffix>-start)?\(")
_ARR_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _ARR_RE.findall(shape_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-category and total collective output bytes in the module."""
    out = {c: 0 for c in COLLECTIVES}
    counts = {c: 0 for c in COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        out[op] += _shape_bytes(m.group("shape"))
        counts[op] += 1
    return dict(bytes_by_op=out, counts=counts,
                total_bytes=sum(out.values()))


# While-loop awareness: collectives inside a while body execute
# trip-count times.  XLA names scan loops `while`; trip counts appear
# in the loop condition against a constant.  We conservatively scale
# body collectives by the trip count when it is statically recoverable.
_WHILE_TRIP_RE = re.compile(
    r"while\(.*?\).*?condition=.*?body=", re.S)


def collective_bytes_scaled(hlo_text: str) -> dict:
    """Like `collective_bytes`, scaling ops inside while bodies by the
    loop trip count (scan-over-layers!).

    HLO post-optimization text lists computations sequentially; ops in
    a while body computation appear under its definition.  We detect
    computations referenced as `body=%name` together with a constant
    trip count pattern `s32[] constant(N)` compared in the matching
    `condition=%cond` computation.
    """
    # map computation name -> text block
    blocks = {}
    cur = None
    for line in hlo_text.splitlines():
        m = re.match(r"^\s*%?([\w.\-]+)\s*\([^)]*\)\s*->", line)
        if m and ("{" in line or line.rstrip().endswith("{")):
            cur = m.group(1)
            blocks[cur] = []
        elif line.startswith("ENTRY"):
            cur = "__entry__"
            blocks[cur] = []
        if cur is not None:
            blocks[cur].append(line)

    # find while ops: body=%B condition=%C ; trip count from C's constant
    trip_of_body = {}
    for name, lines in blocks.items():
        for line in lines:
            m = re.search(r"while\(", line)
            if not m:
                continue
            mb = re.search(r"body=%?([\w.\-]+)", line)
            mc = re.search(r"condition=%?([\w.\-]+)", line)
            if not mb or not mc:
                continue
            trip = None
            cond_lines = blocks.get(mc.group(1), [])
            for cl in cond_lines:
                mt = re.search(r"constant\((\d+)\)", cl)
                if mt:
                    trip = int(mt.group(1))
            if trip:
                trip_of_body[mb.group(1)] = trip

    out = {c: 0 for c in COLLECTIVES}
    counts = {c: 0 for c in COLLECTIVES}
    for name, lines in blocks.items():
        scale = trip_of_body.get(name, 1)
        for line in lines:
            m = _OP_RE.search(line)
            if not m:
                continue
            op = m.group("op")
            out[op] += _shape_bytes(m.group("shape")) * scale
            counts[op] += 1
    return dict(bytes_by_op=out, counts=counts,
                total_bytes=sum(out.values()))
