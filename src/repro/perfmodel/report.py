"""Roofline report generation from the dry-run JSON records."""
from __future__ import annotations

import json
import os

from repro.configs import registry as cfgs
from repro.configs.shapes import SHAPE_ORDER

DEFAULT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "reports", "dryrun")


def load_records(report_dir: str = DEFAULT_DIR, mesh: str = "pod") -> list:
    out = []
    d = os.path.join(report_dir, mesh)
    if not os.path.isdir(d):
        return out
    for arch in cfgs.ARCH_ORDER:
        for shape in SHAPE_ORDER:
            f = os.path.join(d, f"{arch}__{shape}.json")
            if os.path.exists(f):
                with open(f) as fh:
                    out.append(json.load(fh))
    return out


def _fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:7.2f}s "
    return f"{x * 1e3:7.2f}ms"


def roofline_table(records: list, *, markdown: bool = True) -> str:
    """§Roofline table: three terms, bottleneck, useful ratio."""
    hdr = ("arch", "shape", "GiB/dev", "compute", "memory", "collective",
           "bound", "useful", "frac-of-roof")
    rows = []
    for r in records:
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / dom if dom else 0.0
        rows.append((
            r["arch"], r["shape"],
            f"{r['bytes_per_device'] / 2 ** 30:6.2f}",
            _fmt_s(r["compute_s"]), _fmt_s(r["memory_s"]),
            _fmt_s(r["collective_s"]), r["bottleneck"],
            f"{r['useful_ratio']:5.2f}", f"{frac:5.2f}",
        ))
    if markdown:
        lines = ["| " + " | ".join(hdr) + " |",
                 "|" + "---|" * len(hdr)]
        lines += ["| " + " | ".join(str(c) for c in row) + " |"
                  for row in rows]
        return "\n".join(lines)
    w = [max(len(str(x)) for x in col) for col in zip(hdr, *rows)]
    lines = ["  ".join(str(h).ljust(wi) for h, wi in zip(hdr, w))]
    lines += ["  ".join(str(c).ljust(wi) for c, wi in zip(row, w))
              for row in rows]
    return "\n".join(lines)


def skipped_cells() -> list:
    out = []
    for a in cfgs.ARCH_ORDER:
        for s in cfgs.skip_shapes(a):
            out.append((a, s))
    return out


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--dir", default=DEFAULT_DIR)
    args = ap.parse_args()
    recs = load_records(args.dir, args.mesh)
    print(roofline_table(recs, markdown=False))
    print(f"\n{len(recs)} cells; skipped (by design): "
          f"{skipped_cells()}")


if __name__ == "__main__":
    main()
