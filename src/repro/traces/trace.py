"""Compact JAX-native application-trace representation.

The third perspective of the paper — the *application* — needs real
access patterns, not just the Mess pace generator.  A `Trace` is a
fixed-shape, batchable encoding of one application's memory behaviour:

* ``delta``  — per-access cache-line *delta* from the previous access.
  Deltas (not absolute addresses) keep the encoding compact, let one
  trace be sharded across the 23 traffic cores by adding per-core base
  offsets, and make footprint wrapping a single modulo.
* ``is_write`` — read/write flag per access.
* ``dep``    — dependency marker: a 1 means the access needs the
  *previous* access's response before it can issue (a pointer-chase /
  linked-traversal edge).  This is what lets latency-bound semantics
  survive ``vmap``: the replay frontend turns dep-runs into serialized
  issue at the bound-phase load-to-use latency instead of trying to
  track per-access completion events (which would be data-dependent
  control flow).

All fields are (L,) arrays plus two per-trace scalars, so a suite of
applications stacks to a leading batch axis and replays under one
``jax.vmap``-ed compile.  Arrays are padded by at least one bound-phase
slice beyond ``length`` so windowed `dynamic_slice` reads never clamp
into valid data.

A solo `Trace` is sharded data-parallel across the traffic cores (a
multi-threaded kernel); its multiprogrammed sibling is
`repro.traces.mix.TraceMix` — a per-core trace batch built from
`Trace`s by `assign_traces` (see docs/WORKLOADS.md for the authoring
guide).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.workload import CAP_DEMAND

#: traffic-core trace regions must stay below the chase-probe region
#: (bit 31) — 24 cores x footprint must fit in 2^31 lines.
MAX_FOOTPRINT_LINES = 1 << 26


class Trace(NamedTuple):
    """One application's access trace (or a batch, with a leading axis)."""

    delta: jnp.ndarray            # (L,) int32 line delta vs previous
    is_write: jnp.ndarray         # (L,) int32 0/1
    dep: jnp.ndarray              # (L,) int32 0/1 depends-on-previous
    length: jnp.ndarray           # ()  int32 valid prefix
    footprint_lines: jnp.ndarray  # ()  int32 per-core footprint (mod wrap)

    @property
    def n_slots(self) -> int:
        return self.delta.shape[-1]


def make_trace(delta, is_write, dep, footprint_lines: int) -> Trace:
    """Build a `Trace` from host arrays, padding for windowed slicing."""
    delta = np.asarray(delta, np.int32)
    is_write = np.asarray(is_write, np.int32)
    dep = np.asarray(dep, np.int32)
    if not (delta.shape == is_write.shape == dep.shape) or delta.ndim != 1:
        raise ValueError("delta/is_write/dep must be equal-length 1-D")
    if not 0 < footprint_lines <= MAX_FOOTPRINT_LINES:
        raise ValueError(
            f"footprint_lines must be in (0, {MAX_FOOTPRINT_LINES}]")
    n = delta.shape[0]
    pad = CAP_DEMAND
    z = lambda a: np.pad(a, (0, pad))
    return Trace(
        delta=jnp.asarray(z(delta)),
        is_write=jnp.asarray(z(is_write)),
        dep=jnp.asarray(z(dep)),
        length=jnp.asarray(n, jnp.int32),
        footprint_lines=jnp.asarray(footprint_lines, jnp.int32),
    )


def stack_traces(traces: list[Trace]) -> Trace:
    """Stack per-app traces to a batch, right-padding to a common L.

    The result replays under ``jax.vmap`` as one compiled program over
    the application axis; per-app ``length`` keeps short traces honest.
    """
    L = max(t.n_slots for t in traces)

    def padded(t: Trace):
        pad = L - t.n_slots
        return t._replace(
            delta=jnp.pad(t.delta, (0, pad)),
            is_write=jnp.pad(t.is_write, (0, pad)),
            dep=jnp.pad(t.dep, (0, pad)),
        )

    return jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *[padded(t) for t in traces])


def trace_stats(trace: Trace) -> dict:
    """Host-side summary of one (unbatched) trace."""
    n = int(trace.length)
    wr = np.asarray(trace.is_write)[:n]
    dep = np.asarray(trace.dep)[:n]
    return dict(
        accesses=n,
        write_frac=float(wr.mean()) if n else 0.0,
        dep_frac=float(dep.mean()) if n else 0.0,
        footprint_mb=float(trace.footprint_lines) * 64 / 2**20,
    )
