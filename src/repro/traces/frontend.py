"""Trace-replay bound-phase frontend (the application perspective).

`TraceFrontend` plugs into `platform.run_frontend` exactly where the
Mess pace generator does (`workload.MessFrontend`), so replayed
applications run under the *same* bound/weave windows, immediate-
response latency, PI controller, and MSHR closed loop — the decoupling
bug and its corrections apply to real access patterns, not just
synthetic sweeps.

Replay model (all fixed-shape, `vmap`-safe):

* The trace is sharded data-parallel across the 23 traffic cores: every
  core replays the same delta stream against its own base region
  (``core * footprint``), i.e. a multi-threaded kernel with per-core
  shards.  One shared cursor tracks progress.
* Per window the frontend slices the next `CAP_DEMAND` accesses
  (`dynamic_slice` at the cursor) and prices each in CPU cycles:
  an *independent* access costs the MSHR-closed-loop issue interval
  (``window_cycles / budget`` — Little's-law pacing, identical to the
  Mess generator's throttle), a *dependent* access costs the full
  bound-phase load-to-use latency (cache path + NOC + immediate
  response) because it cannot issue before the previous response.
  The consumed prefix is the accesses whose cumulative cost fits the
  window (+ carry-over), which is precisely how far the application
  advances this window.
* The pointer-chase probe core keeps running (`workload.chase_probe`):
  it is the platform's latency instrument, shared by every frontend.

Abstraction (documented, Mess-style): demand rejected by a full channel
queue is not replayed — with 256-deep queues this is rare, and dropping
preserves pressure statistically (same policy as the pace generator).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from typing import NamedTuple

from repro.core import workload
from repro.core.workload import (CAND, CAP_DEMAND, CHASE_CORE, N_CORES,
                                 N_TRAFFIC, Candidates, WorkloadConfig)
from repro.traces.trace import Trace


class TraceState(NamedTuple):
    pos: jnp.ndarray          # () int32 shared cursor into the trace
    line_cum: jnp.ndarray     # () int32 running delta sum at the cursor
    carry: jnp.ndarray        # () int32 leftover CPU cycles
    chase_seq: jnp.ndarray    # () int32 probe stream position
    chase_carry: jnp.ndarray  # () int32 probe loop carry


class TraceFrontend:
    """Replay one application trace through the bound phase.

    Closes over the (possibly traced/batched) `Trace` arrays, so
    ``run_frontend(cfg, TraceFrontend(trace, wcfg))`` vmaps across a
    stacked application axis with a single compiled program.
    """

    def __init__(self, trace: Trace, cfg: WorkloadConfig):
        self.trace = trace
        self.cfg = cfg

    def init_state(self) -> TraceState:
        """Fresh replay cursor at the head of the trace (all zeros)."""
        z = jnp.zeros((), jnp.int32)
        return TraceState(pos=z, line_cum=z, carry=z,
                          chase_seq=z, chase_carry=z)

    def bound(self, state: TraceState, l_ir_cycles, budget, window_cycles):
        """One window's bound phase: price + emit the next trace slice.

        Args:
            state: replay cursor (`TraceState`).
            l_ir_cycles: current immediate-response latency, CPU cycles
                (int32, traced; PI-controlled after stage 04).
            budget: per-core MSHR closed-loop demand budget for this
                window (requests, from `workload.littles_law_budget`).
            window_cycles: ZSim window length in CPU cycles (static).
        Returns:
            ``(Candidates, aux)`` — the (24, CAND) candidate requests
            (issue cycles are CPU cycles within the window) and the
            bookkeeping dict `update` folds into the next state.
        """
        tr = self.trace
        cid = jnp.arange(N_CORES, dtype=jnp.int32)[:, None]     # (24,1)
        j = jnp.arange(CAND, dtype=jnp.int32)[None, :]          # (1,CAND)
        jj = jnp.arange(CAP_DEMAND, dtype=jnp.int32)            # (CAP,)
        is_traffic = cid < N_TRAFFIC

        # ---- next CAP_DEMAND accesses at the cursor --------------------
        pos = jnp.minimum(state.pos, tr.length)
        sl = lambda a: jax.lax.dynamic_slice(a, (pos,), (CAP_DEMAND,))
        delta = sl(tr.delta)
        is_wr = sl(tr.is_write)
        dep = sl(tr.dep)
        in_range = pos + jj < tr.length

        # ---- the shared latency probe ----------------------------------
        cv, c_line, c_issue, chase_iters, chase_carry, iter_cycles = \
            workload.chase_probe(state.chase_seq, state.chase_carry,
                                 l_ir_cycles, self.cfg, window_cycles)
        c_valid = (cid == CHASE_CORE) & cv[None, :]

        # ---- cycle pricing under the MSHR closed loop ------------------
        # a dep-marked access is priced exactly like one probe iteration
        # (bound-phase load-to-use); independents at the Little's-law
        # issue interval
        dep_cycles = iter_cycles
        ind_cycles = jnp.maximum(window_cycles // jnp.maximum(budget, 1), 1)
        cost = jnp.where(dep == 1, dep_cycles, ind_cycles)
        fin = jnp.cumsum(cost)                       # finish cycle of k-th
        start_c = fin - cost
        avail = window_cycles + state.carry
        take = in_range & (fin <= avail)             # prefix by monotone fin
        n_take = jnp.sum(take.astype(jnp.int32))
        used = jnp.sum(jnp.where(take, cost, 0))
        # carry at most one window of slack; none once the trace is done
        new_carry = jnp.clip(jnp.where(jnp.any(in_range), avail - used, 0),
                             0, window_cycles)

        # ---- absolute lines: per-core shard base + wrapped delta sum ---
        # Each core gets a hashed *phase* within its shard: real
        # data-parallel threads do not run in address lockstep, and
        # without the stagger all 23 cores hit the same channel/bank
        # residues simultaneously (serializing 6 channels down to ~3).
        cum = state.line_cum + jnp.cumsum(delta)                # (CAP,)
        phase = (cid.astype(jnp.uint32) * jnp.uint32(2654435761)
                 % tr.footprint_lines.astype(jnp.uint32)
                 ).astype(jnp.int32)                            # (24,1)
        idx = jnp.remainder(cum[None, :] + phase,
                            tr.footprint_lines)                 # (24,CAP)
        base = (cid * tr.footprint_lines).astype(jnp.uint32)    # (24,1)
        t_line = base + idx.astype(jnp.uint32)
        t_valid = is_traffic & take[None, :]
        t_issue = jnp.minimum(start_c, window_cycles - 1)

        # pad the demand slice up to CAND slots (no prefetch slots used)
        padc = CAND - CAP_DEMAND
        pad2 = lambda a, v: jnp.pad(a, ((0, 0), (0, padc)),
                                    constant_values=v)
        pad_t = lambda a, v: jnp.pad(a, (0, padc), constant_values=v)

        cand = Candidates(
            valid=pad2(t_valid, False) | c_valid,
            line=jnp.where(is_traffic, pad2(t_line, 0), c_line),
            is_write=jnp.where(is_traffic,
                               pad_t(is_wr, 0)[None, :] == 1, False),
            issue_cycle=jnp.where(is_traffic, pad_t(t_issue, 0)[None, :],
                                  c_issue).astype(jnp.int32),
            is_chase=c_valid,
            is_pf=jnp.zeros((N_CORES, CAND), bool),
        )
        aux = dict(n_take=n_take, new_carry=new_carry,
                   line_cum_next=state.line_cum
                   + jnp.sum(jnp.where(take, delta, 0)),
                   chase_iters=chase_iters, chase_carry=chase_carry)
        return cand, aux

    def update(self, state: TraceState, aux, acc_demand) -> TraceState:
        """Advance the cursor past the accesses consumed this window.

        ``acc_demand`` (per-core accepted demand counts) is unused:
        rejected demand is dropped (see module doc) so the cursor moves
        by the bound-phase take, not the queue-accept count.
        """
        del acc_demand   # rejected demand is dropped (see module doc)
        return TraceState(
            pos=state.pos + aux["n_take"],
            line_cum=aux["line_cum_next"],
            carry=aux["new_carry"],
            chase_seq=state.chase_seq + aux["chase_iters"],
            chase_carry=aux["chase_carry"],
        )

    def progress(self, state: TraceState):
        """Monotone trace position (accesses consumed); the replay
        engine compares it against ``trace.length`` to find the
        completion window."""
        return state.pos
