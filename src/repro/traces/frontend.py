"""Trace-replay bound-phase frontend (the application perspective).

`TraceFrontend` plugs into `platform.run_frontend` exactly where the
Mess pace generator does (`workload.MessFrontend`), so replayed
applications run under the *same* bound/weave windows, immediate-
response latency, PI controller, and MSHR closed loop — the decoupling
bug and its corrections apply to real access patterns, not just
synthetic sweeps.

Replay model (all fixed-shape, `vmap`-safe), generalized to **per-core
cursors**:

* Every core owns its own replay cursor into its own stream.  Two
  drive modes share one code path:

  - a solo `Trace`: the stream is sharded data-parallel across all
    traffic cores — every core replays the same delta sequence against
    its own base region (``core * footprint``), i.e. a multi-threaded
    kernel with per-core shards (cursors advance in lockstep because
    pricing is address-independent);
  - a `TraceMix` (`repro.traces.mix`): a ``(n_cores,)``-indexed batch
    of traces with per-core lengths, footprints, and phase offsets — a
    multiprogrammed workload, each core pricing its *own* stream.

* Per window the frontend slices each core's next `CAP_DEMAND`
  accesses (per-core `dynamic_slice` at that core's cursor) and prices
  each in CPU cycles: an *independent* access costs the MSHR-closed-
  loop issue interval (``window_cycles / budget`` — Little's-law
  pacing, identical to the Mess generator's throttle), a *dependent*
  access costs the full bound-phase load-to-use latency (cache path +
  NOC + immediate response) because it cannot issue before the
  previous response.  The consumed prefix is the accesses whose
  cumulative cost fits the window (+ per-core carry-over), which is
  precisely how far that core's application advances this window.
* The pointer-chase probe core keeps running (`workload.chase_probe`):
  it is the platform's latency instrument, shared by every frontend
  (and by every socket — see `WorkloadConfig.n_sockets`).

Abstraction (documented, Mess-style): demand rejected by a full channel
queue is not replayed — with 256-deep queues this is rare, and dropping
preserves pressure statistically (same policy as the pace generator).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from typing import NamedTuple

from repro.core import workload
from repro.core.workload import (CAND, CAP_DEMAND, Candidates,
                                 WorkloadConfig)
from repro.traces.mix import TraceMix
from repro.traces.trace import Trace


class TraceState(NamedTuple):
    pos: jnp.ndarray          # (n_cores,) int32 per-core trace cursor
    line_cum: jnp.ndarray     # (n_cores,) int32 running delta sum
    carry: jnp.ndarray        # (n_cores,) int32 leftover CPU cycles
    chase_seq: jnp.ndarray    # () int32 probe stream position
    chase_carry: jnp.ndarray  # () int32 probe loop carry


class TraceFrontend:
    """Replay an application trace (or a per-core mix) through the
    bound phase.

    Closes over the (possibly traced/batched) `Trace` / `TraceMix`
    arrays, so ``run_frontend(cfg, TraceFrontend(trace, wcfg))`` vmaps
    across a stacked application (or mix) axis with a single compiled
    program.
    """

    def __init__(self, trace: Trace | TraceMix, cfg: WorkloadConfig):
        self.trace = trace
        self.cfg = cfg
        self.is_mix = isinstance(trace, TraceMix)
        if self.is_mix and trace.delta.shape[-2] != cfg.n_cores:
            raise ValueError(
                f"mix has {trace.delta.shape[-2]} cores but the platform "
                f"has {cfg.n_cores} ({cfg.n_sockets} socket(s))")

    # ---- per-core views of the trace arrays ---------------------------

    def _per_core_slice(self, pos):
        """(n_cores, CAP_DEMAND) delta/write/dep slices at each cursor."""
        tr = self.trace
        sl = lambda a, p: jax.lax.dynamic_slice(a, (p,), (CAP_DEMAND,))
        if self.is_mix:
            take = jax.vmap(sl)
            return (take(tr.delta, pos), take(tr.is_write, pos),
                    take(tr.dep, pos))
        take = jax.vmap(sl, in_axes=(None, 0))
        return (take(tr.delta, pos), take(tr.is_write, pos),
                take(tr.dep, pos))

    def _targets(self):
        """(n_cores,) per-core access counts (0 = idle / chase core)."""
        cid = jnp.arange(self.cfg.n_cores, dtype=jnp.int32)
        if self.is_mix:
            return self.trace.length
        return jnp.where(cid < self.cfg.n_traffic, self.trace.length, 0)

    def _footprints(self):
        """(n_cores,) per-core footprints and the region stride."""
        if self.is_mix:
            return self.trace.footprint_lines, self.trace.region_lines
        f = jnp.broadcast_to(self.trace.footprint_lines,
                             (self.cfg.n_cores,))
        return f, self.trace.footprint_lines

    def init_state(self) -> TraceState:
        """Fresh per-core cursors (at each core's phase offset)."""
        n = self.cfg.n_cores
        z = jnp.zeros((n,), jnp.int32)
        zs = jnp.zeros((), jnp.int32)
        if self.is_mix:
            return TraceState(pos=self.trace.pos0,
                              line_cum=self.trace.line_cum0,
                              carry=z, chase_seq=zs, chase_carry=zs)
        return TraceState(pos=z, line_cum=z, carry=z,
                          chase_seq=zs, chase_carry=zs)

    def bound(self, state: TraceState, l_ir_cycles, budget, window_cycles):
        """One window's bound phase: price + emit each core's slice.

        Args:
            state: per-core replay cursors (`TraceState`).
            l_ir_cycles: current immediate-response latency, CPU cycles
                (int32, traced; PI-controlled after stage 04).
            budget: per-core MSHR closed-loop demand budget for this
                window (requests, from `workload.littles_law_budget`).
            window_cycles: ZSim window length in CPU cycles (static).
        Returns:
            ``(Candidates, aux)`` — the (n_cores, CAND) candidate
            requests (issue cycles are CPU cycles within the window)
            and the bookkeeping dict `update` folds into the next state.
        """
        cfg = self.cfg
        n_cores = cfg.n_cores
        cid = jnp.arange(n_cores, dtype=jnp.int32)[:, None]     # (N,1)
        jj = jnp.arange(CAP_DEMAND, dtype=jnp.int32)[None, :]   # (1,CAP)
        is_traffic = cid < cfg.n_traffic

        # ---- each core's next CAP_DEMAND accesses at its cursor --------
        target = self._targets()                                # (N,)
        n_slots = self.trace.delta.shape[-1]
        pos = jnp.minimum(state.pos, n_slots - CAP_DEMAND)      # (N,)
        delta, is_wr, dep = self._per_core_slice(pos)           # (N,CAP)
        in_range = pos[:, None] + jj < target[:, None]          # (N,CAP)

        # ---- the shared latency probe ----------------------------------
        cv, c_line, c_issue, chase_iters, chase_carry, iter_cycles = \
            workload.chase_probe(state.chase_seq, state.chase_carry,
                                 l_ir_cycles, cfg, window_cycles)
        c_valid = (cid == cfg.chase_core) & cv[None, :]

        # ---- cycle pricing under the MSHR closed loop ------------------
        # a dep-marked access is priced exactly like one probe iteration
        # (bound-phase load-to-use); independents at the Little's-law
        # issue interval
        dep_cycles = iter_cycles
        ind_cycles = jnp.maximum(window_cycles // jnp.maximum(budget, 1), 1)
        cost = jnp.where(dep == 1, dep_cycles, ind_cycles)      # (N,CAP)
        fin = jnp.cumsum(cost, axis=1)               # finish cycle of k-th
        start_c = fin - cost
        avail = (window_cycles + state.carry)[:, None]          # (N,1)
        take = in_range & (fin <= avail)             # prefix by monotone fin
        n_take = jnp.sum(take.astype(jnp.int32), axis=1)        # (N,)
        used = jnp.sum(jnp.where(take, cost, 0), axis=1)        # (N,)
        # carry at most one window of slack; none once a stream is done
        new_carry = jnp.clip(
            jnp.where(jnp.any(in_range, axis=1),
                      avail[:, 0] - used, 0),
            0, window_cycles)                                   # (N,)

        # ---- absolute lines: per-core region base + wrapped delta sum -
        # Each core gets a hashed *phase* within its footprint: real
        # data-parallel threads do not run in address lockstep, and
        # without the stagger all traffic cores hit the same channel/
        # bank residues simultaneously (serializing the channels).
        foot, region = self._footprints()                       # (N,), ()
        cum = state.line_cum[:, None] + jnp.cumsum(delta, axis=1)
        phase = (cid[:, 0].astype(jnp.uint32) * jnp.uint32(2654435761)
                 % jnp.maximum(foot, 1).astype(jnp.uint32)
                 ).astype(jnp.int32)                            # (N,)
        idx = jnp.remainder(cum + phase[:, None],
                            jnp.maximum(foot, 1)[:, None])      # (N,CAP)
        base = (cid[:, 0] * region).astype(jnp.uint32)[:, None]  # (N,1)
        t_line = base + idx.astype(jnp.uint32)
        t_valid = is_traffic & take
        t_issue = jnp.minimum(start_c, window_cycles - 1)

        # pad the demand slice up to CAND slots (no prefetch slots used)
        padc = CAND - CAP_DEMAND
        pad2 = lambda a, v: jnp.pad(a, ((0, 0), (0, padc)),
                                    constant_values=v)

        cand = Candidates(
            valid=pad2(t_valid, False) | c_valid,
            line=jnp.where(is_traffic, pad2(t_line, 0), c_line),
            is_write=jnp.where(is_traffic, pad2(is_wr, 0) == 1, False),
            issue_cycle=jnp.where(is_traffic, pad2(t_issue, 0),
                                  c_issue).astype(jnp.int32),
            is_chase=c_valid,
            is_pf=jnp.zeros((n_cores, CAND), bool),
        )
        aux = dict(n_take=n_take, new_carry=new_carry,
                   line_cum_next=state.line_cum
                   + jnp.sum(jnp.where(take, delta, 0), axis=1),
                   chase_iters=chase_iters, chase_carry=chase_carry)
        return cand, aux

    def update(self, state: TraceState, aux, acc_demand) -> TraceState:
        """Advance each cursor past the accesses consumed this window.

        ``acc_demand`` (per-core accepted demand counts) is unused:
        rejected demand is dropped (see module doc) so the cursors move
        by the bound-phase take, not the queue-accept count.
        """
        del acc_demand   # rejected demand is dropped (see module doc)
        return TraceState(
            pos=state.pos + aux["n_take"],
            line_cum=aux["line_cum_next"],
            carry=aux["new_carry"],
            chase_seq=state.chase_seq + aux["chase_iters"],
            chase_carry=aux["chase_carry"],
        )

    def progress(self, state: TraceState):
        """(n_cores,) monotone per-core trace positions (accesses
        consumed); the replay engine compares them against the per-core
        targets to find each core's completion window."""
        return state.pos
