"""Reference runtime anchors: what each application *should* take.

The paper validates simulators against the measured machine; for the
application perspective that means per-application runtime on the real
server.  We derive analytic anchors from the per-preset measured Mess
curve families in `repro.core.reference` with a small closed-system
model:

* dependent accesses serialize at the measured load-to-use latency
  (a pointer chase runs at exactly one access per latency);
* independent accesses stream at the Little's-law rate of `MSHR_CAP`
  outstanding lines per core, capped by (a) the machine's per-mix
  maximum bandwidth share and (b) the frontend issue ceiling — a core
  retires at most `CAP_DEMAND` demands per 1000-cycle window, the same
  bound the platform's bound phase enforces.  Both caps are
  socket-aware: ``n_sockets`` sockets carry ``24 * n_sockets - 1``
  traffic cores, so per-core bandwidth share shrinks while total
  frontend capacity grows — on HBM2e a second socket is what lets the
  anchors (and the platform) reach the device knee at all;
* latency and bandwidth are solved as a fixed point (more traffic ->
  higher latency -> fewer outstanding-lines per second).

Multiprogrammed mixes get the same treatment (`anchor_mix_ms`): one
*joint* fixed point where every application contributes traffic to the
shared curve, so a latency-bound app's anchor inherits the queueing
delay its streaming neighbours create — the real-machine behaviour a
per-app solo anchor cannot express.

These anchors are *references*, not measurements — they inherit the
per-preset anchor points (e.g. 89 ns unloaded / 120 GB/s saturation
for the paper's DDR4-2666 Skylake) and serve as the ground truth for
the benchmark's MAPE, playing the role of the paper's real-hardware
column.  Adding a new device preset means adding its curve family to
`repro.core.reference._FAMILIES`; this module picks it up by name
(see docs/VALIDATION.md for the full recipe).
"""
from __future__ import annotations

import numpy as np

from repro.core import reference
from repro.core.timing import CpuParams
from repro.core.workload import CAP_DEMAND, MSHR_CAP, N_CORES_PER_SOCKET

LINE_BYTES = 64

_CPU = CpuParams()
#: frontend issue ceiling: lines / ns / core the bound phase can retire
_WINDOW_RATE = CAP_DEMAND / (_CPU.window_cycles * _CPU.cpu_ps_per_clk * 1e-3)


def n_traffic_cores(n_sockets: int = 1) -> int:
    """Traffic cores of an ``n_sockets`` frontend (one shared probe)."""
    return N_CORES_PER_SOCKET * n_sockets - 1


def anchor_runtime_ms(trace, preset: str = "ddr4_2666",
                      iters: int = 8, n_sockets: int = 1) -> float:
    """Analytic real-system runtime of one (unbatched) trace, in ms.

    Args:
        trace: an unbatched `repro.traces.Trace`.
        preset: device preset whose reference curves anchor the model.
        iters: fixed-point iterations (converges in a handful).
        n_sockets: traffic sockets of the modeled machine (matches the
            platform's `StageConfig.n_sockets`).
    Returns:
        Runtime in milliseconds.  The trace is sharded across all
        traffic cores exactly as the replay frontend does, so anchor
        and prediction describe the same execution.
    """
    from repro.traces.trace import trace_stats

    st = trace_stats(trace)
    n = st["accesses"]
    if n == 0:
        return 0.0
    n_traffic = n_traffic_cores(n_sockets)
    read_frac = 1.0 - st["write_frac"]
    n_dep = st["dep_frac"] * n
    n_ind = n - n_dep

    bw = 1.0                                   # GB/s, fixed-point seed
    t_ns = 1.0
    for _ in range(iters):
        lat = float(reference.latency_ns(bw, read_frac, preset))
        # per-core independent service rate (lines/ns), Little's law
        rate_core = MSHR_CAP / lat
        bw_cap = reference.max_bandwidth_gbs(read_frac, preset)
        rate_cap = bw_cap / (n_traffic * LINE_BYTES)  # GB/s -> lines/ns/core
        rate = min(rate_core, rate_cap, _WINDOW_RATE)
        # every core replays the full stream against its own shard
        t_ns = n_dep * lat + n_ind / rate
        bw = n_traffic * n * LINE_BYTES / t_ns         # bytes/ns = GB/s
    return t_ns * 1e-6


def anchor_suite_ms(traces, preset: str = "ddr4_2666",
                    n_sockets: int = 1) -> np.ndarray:
    """Per-trace `anchor_runtime_ms` over a list of traces (ms array)."""
    return np.asarray([anchor_runtime_ms(t, preset, n_sockets=n_sockets)
                       for t in traces])


def anchor_mix_ms(traces, cores_per_app, preset: str = "ddr4_2666",
                  iters: int = 12, n_sockets: int = 1) -> np.ndarray:
    """Per-app real-system runtimes of a multiprogrammed mix, in ms.

    One joint fixed point over the shared bandwidth-latency curve:
    every app's cores contribute traffic, the aggregate bandwidth sets
    the latency every app observes, and each app's independent-stream
    rate is capped by its *share* of the machine's saturation
    bandwidth (proportional to its core count — the fair-share outcome
    of per-channel FR-FCFS under symmetric demand).

    Args:
        traces: the mix's applications (unbatched `Trace`s).
        cores_per_app: traffic cores running each app (same order);
            the total must fit the ``n_sockets`` frontend.
        preset: device preset whose curve family anchors the model.
        iters: fixed-point iterations.
        n_sockets: traffic sockets of the modeled machine.
    Returns:
        (n_apps,) runtimes in milliseconds — each entry comparable to
        `anchor_runtime_ms` of the same trace when run *alone*, except
        for the contention the rest of the mix adds.
    """
    from repro.traces.trace import trace_stats

    stats = [trace_stats(t) for t in traces]
    cores = np.asarray(cores_per_app, np.int64)
    if len(stats) != len(cores):
        raise ValueError("need one core count per trace")
    n_traffic = n_traffic_cores(n_sockets)
    if cores.sum() > n_traffic:
        raise ValueError(f"{cores.sum()} cores assigned but the "
                         f"{n_sockets}-socket frontend has {n_traffic}")

    n = np.asarray([s["accesses"] for s in stats], np.float64)
    n_dep = np.asarray([s["dep_frac"] for s in stats]) * n
    n_ind = n - n_dep
    read_frac = float(np.average(
        [1.0 - s["write_frac"] for s in stats],
        weights=np.maximum(n * cores, 1)))

    t_ns = np.ones(len(stats))
    bw_total = 1.0
    for _ in range(iters):
        lat = float(reference.latency_ns(bw_total, read_frac, preset))
        bw_cap = reference.max_bandwidth_gbs(read_frac, preset)
        rate_core = MSHR_CAP / lat
        # per-core share of saturation bandwidth: proportional split
        # across every *active* traffic core of the mix
        active = max(int(cores.sum()), 1)
        rate_cap = bw_cap / (active * LINE_BYTES)
        rate = min(rate_core, rate_cap, _WINDOW_RATE)
        t_ns = n_dep * lat + n_ind / rate
        with np.errstate(divide="ignore", invalid="ignore"):
            bw_app = np.where(t_ns > 0,
                              cores * n * LINE_BYTES / t_ns, 0.0)
        bw_total = float(bw_app.sum())
    return t_ns * 1e-6


def mape(predicted_ms, anchor_ms) -> float:
    """Mean absolute percentage error of predicted vs anchor runtimes."""
    p = np.asarray(predicted_ms, np.float64)
    a = np.asarray(anchor_ms, np.float64)
    return float(np.mean(np.abs(p - a) / np.maximum(a, 1e-12)) * 100.0)
