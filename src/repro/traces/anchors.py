"""Reference runtime anchors: what each application *should* take.

The paper validates simulators against the measured machine; for the
application perspective that means per-application runtime on the real
server.  We derive analytic anchors from the per-preset measured Mess
curve families in `repro.core.reference` with a small closed-system
model:

* dependent accesses serialize at the measured load-to-use latency
  (a pointer chase runs at exactly one access per latency);
* independent accesses stream at the Little's-law rate of `MSHR_CAP`
  outstanding lines per core, capped by (a) the machine's per-mix
  maximum bandwidth share and (b) the frontend issue ceiling — a core
  retires at most `CAP_DEMAND` demands per 1000-cycle window, the same
  bound the platform's bound phase enforces (on fast devices such as
  HBM2e this frontend bound, not the memory device, is the limiter —
  exactly as on real single-socket hardware);
* latency and bandwidth are solved as a fixed point (more traffic ->
  higher latency -> fewer outstanding-lines per second).

These anchors are *references*, not measurements — they inherit the
per-preset anchor points (e.g. 89 ns unloaded / 120 GB/s saturation
for the paper's DDR4-2666 Skylake) and serve as the ground truth for
the benchmark's MAPE, playing the role of the paper's real-hardware
column.  Adding a new device preset means adding its curve family to
`repro.core.reference._FAMILIES`; this module picks it up by name
(see docs/VALIDATION.md for the full recipe).
"""
from __future__ import annotations

import numpy as np

from repro.core import reference
from repro.core.timing import CpuParams
from repro.core.workload import CAP_DEMAND, MSHR_CAP, N_TRAFFIC

LINE_BYTES = 64

_CPU = CpuParams()
#: frontend issue ceiling: lines / ns / core the bound phase can retire
_WINDOW_RATE = CAP_DEMAND / (_CPU.window_cycles * _CPU.cpu_ps_per_clk * 1e-3)


def anchor_runtime_ms(trace, preset: str = "ddr4_2666",
                      iters: int = 8) -> float:
    """Analytic real-system runtime of one (unbatched) trace, in ms.

    Args:
        trace: an unbatched `repro.traces.Trace`.
        preset: device preset whose reference curves anchor the model.
        iters: fixed-point iterations (converges in a handful).
    Returns:
        Runtime in milliseconds.  The trace is sharded across
        `N_TRAFFIC` cores exactly as the replay frontend does, so
        anchor and prediction describe the same execution.
    """
    from repro.traces.trace import trace_stats

    st = trace_stats(trace)
    n = st["accesses"]
    if n == 0:
        return 0.0
    read_frac = 1.0 - st["write_frac"]
    n_dep = st["dep_frac"] * n
    n_ind = n - n_dep

    bw = 1.0                                   # GB/s, fixed-point seed
    t_ns = 1.0
    for _ in range(iters):
        lat = float(reference.latency_ns(bw, read_frac, preset))
        # per-core independent service rate (lines/ns), Little's law
        rate_core = MSHR_CAP / lat
        bw_cap = reference.max_bandwidth_gbs(read_frac, preset)
        rate_cap = bw_cap / (N_TRAFFIC * LINE_BYTES)   # GB/s -> lines/ns/core
        rate = min(rate_core, rate_cap, _WINDOW_RATE)
        # every core replays the full stream against its own shard
        t_ns = n_dep * lat + n_ind / rate
        bw = N_TRAFFIC * n * LINE_BYTES / t_ns         # bytes/ns = GB/s
    return t_ns * 1e-6


def anchor_suite_ms(traces, preset: str = "ddr4_2666") -> np.ndarray:
    """Per-trace `anchor_runtime_ms` over a list of traces (ms array)."""
    return np.asarray([anchor_runtime_ms(t, preset) for t in traces])


def mape(predicted_ms, anchor_ms) -> float:
    """Mean absolute percentage error of predicted vs anchor runtimes."""
    p = np.asarray(predicted_ms, np.float64)
    a = np.asarray(anchor_ms, np.float64)
    return float(np.mean(np.abs(p - a) / np.maximum(a, 1e-12)) * 100.0)
