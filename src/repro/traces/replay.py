"""Batched multi-application replay engine (device-sharded).

One compiled program replays a whole application suite: the stacked
`Trace` batch maps over `platform.run_frontend`, with the application
axis sharded across every available device by
`repro.core.shard.sharded_vmap` (bit-identical plain-vmap fallback on
one device), so N applications share a single XLA compile per stage —
the same pattern `mess.sweep` uses for pace points.  Stages and device
presets iterate in Python because they differ in *static*
configuration (clock model, scheduler policy, channel/bank geometry),
which changes program shapes; `replay_grid` wraps that iteration so a
full (preset x stage x app) scenario grid is one invocation.

Multiprogrammed workloads ride the same machinery: a `TraceMix`
(per-core trace batch, `repro.traces.mix`) replays through
`replay_mix`, and a *stack* of mixes through `replay_mixes` — the mix
axis is the sharded batch axis, exactly like the app axis of a solo
suite.  The frontend keeps one cursor per core either way, so per-app
runtimes in a mix come back per core and are reduced by `app_id`.

Outputs per application:

* the three views (simulator / interface / application bandwidth and
  latency) — the paper's methodology applied to real access patterns;
* a predicted application *runtime*: the window at which the trace was
  fully consumed (or an extrapolation from the final replay rate when
  the configured window count ends first).
"""
from __future__ import annotations

import functools

import jax
import numpy as np

from repro.core.platform import StageConfig, run_frontend
from repro.core.shard import sharded_vmap
from repro.traces.frontend import TraceFrontend
from repro.traces.mix import TraceMix
from repro.traces.trace import Trace

#: per-app result keys that are plain per-window scalars in the views
VIEW_KEYS = ("sim_bw_gbs", "sim_lat_ns", "if_bw_gbs", "if_lat_ns",
             "app_bw_gbs", "app_lat_ns", "chase_lat_ns", "n_rd", "n_wr")


@functools.lru_cache(maxsize=None)
def _replay_fn(cfg: StageConfig, donate: bool = False):
    """One compiled program: the app/mix axis is the sharded batch axis."""

    def one(trace):
        views, outs = run_frontend(cfg, TraceFrontend(
            trace, cfg.workload_config()))
        out = dict({k: views[k] for k in VIEW_KEYS},
                   weave_sat=views["weave_sat"], progress=outs.progress)
        if cfg.telemetry:
            # three-perspective telemetry planes (`repro.obs`): full
            # (W, ...) per-window series, flat keys so the batch axis
            # vmaps and the dense fallback's row merge work unchanged
            out.update({k: v for k, v in views.items()
                        if k.startswith("tele_")})
        return out

    return sharded_vmap(one, donate=donate)


def _replay_exact(cfg: StageConfig, batch, donate: bool) -> dict:
    """Replay a batch, re-running event-budget-saturated rows dense.

    Under the default event weave engine, a row whose windows exhaust
    the static event budget (``weave_sat`` — the exact divergence
    detector) is replayed through the dense reference engine, so the
    returned results are bit-identical to an all-dense replay no
    matter how hot the workload runs.  With ``donate=True`` the input
    buffers are consumed by the first pass, so the fallback is
    unavailable — saturated rows stay flagged in ``weave_sat`` for the
    caller to handle (pre-verify the regime, or keep the default
    ``donate=False``).
    """
    out = jax.device_get(_replay_fn(cfg, donate)(batch))
    out = {k: np.array(v) for k, v in out.items()}
    sat = np.flatnonzero(out["weave_sat"] > 0)
    if sat.size and cfg.weave == "event" and not donate:
        import dataclasses

        cfg_dense = dataclasses.replace(cfg, weave="dense")
        sub = jax.tree_util.tree_map(lambda a: a[sat], batch)
        fixed = jax.device_get(_replay_fn(cfg_dense, False)(sub))
        for k, v in fixed.items():
            if k != "weave_sat":           # keep the diagnostic flag
                out[k][sat] = np.asarray(v)
    return out


def _runtime_windows(progress, target, pos0=None):
    """Per-stream completion from a (..., W, n_cores) progress history.

    Args:
        progress: per-window per-core cursor positions.
        target: (..., n_cores) per-core access counts (0 = idle).
        pos0: (..., n_cores) per-core phase offsets (cursor start);
            extrapolation measures replay rate from here, not from 0,
            so an offset core's head start is not counted as progress.
    Returns:
        ``(runtime_windows, done)`` per core: the 1-based window at
        which the core's stream completed, extrapolated from the final
        replay rate when it did not; idle cores report 0 windows.
    """
    if pos0 is None:
        pos0 = np.zeros_like(target)
    W = progress.shape[-2]
    done = progress >= target[..., None, :]          # (..., W, N)
    any_done = done.any(axis=-2)
    first_done = np.where(any_done, done.argmax(axis=-2) + 1, W)
    advanced = np.maximum(progress[..., -1, :] - pos0, 1)
    est = W * (target - pos0) / advanced
    rt = np.where(any_done, first_done, est).astype(np.float64)
    return np.where(target > 0, rt, 0.0), any_done | (target == 0)


def replay_suite(cfg: StageConfig, traces: Trace,
                 donate: bool = False) -> dict:
    """Replay a stacked trace batch through one stage; host-side dict.

    Args:
        cfg: the stage configuration (clock model, policy, platform).
        traces: a `Trace` with a leading application axis
            (see `stack_traces`); the axis is sharded across devices.
        donate: donate the trace buffers to the compiled replay
            (`repro.core.shard.sharded_vmap`), cutting per-point device
            copies / peak memory for fleet-scale batches.  The batch is
            **consumed** — pass ``True`` only when it is not replayed
            again (e.g. single-stage runs; `replay_stages` reuses the
            batch across stages and must keep the default).
    Returns:
        Numpy arrays keyed by `VIEW_KEYS` (bandwidth GB/s, latency ns)
        plus ``runtime_ms`` / ``runtime_windows`` / ``done`` /
        ``progress_final`` per application.
    """
    wcfg = cfg.workload_config()
    # host-side fields first: after a donating call the buffers are gone
    length = np.asarray(jax.device_get(traces.length))  # (A,)
    # per-core regions must stay below the chase-probe region (bit 31):
    # with two sockets (48 cores) large footprints can reach it
    fmax = int(np.max(np.asarray(jax.device_get(traces.footprint_lines))))
    if wcfg.n_cores * fmax > 1 << 31:
        raise ValueError(
            f"{wcfg.n_cores} cores x footprint {fmax} lines overflows "
            f"the 2^31-line traffic address space (the chase-probe "
            f"region starts at bit 31); shrink the footprint")

    out = _replay_exact(cfg, traces, donate)
    progress = np.asarray(out.pop("progress"))       # (A, W, n_cores)
    out = {k: np.asarray(v) for k, v in out.items()}
    cid = np.arange(wcfg.n_cores)
    target = np.where(cid[None, :] < wcfg.n_traffic,
                      length[:, None], 0)             # (A, n_cores)
    rt, done = _runtime_windows(progress, target)
    traffic = cid < wcfg.n_traffic
    # the app finishes when its slowest core does (lockstep in solo mode)
    runtime_windows = rt[:, traffic].max(axis=1)

    cpu = cfg.platform.cpu
    window_ms = cpu.window_cycles * cpu.cpu_ps_per_clk * 1e-9
    out["done"] = done[:, traffic].all(axis=1)
    out["runtime_windows"] = runtime_windows
    out["runtime_ms"] = runtime_windows * window_ms
    out["progress_final"] = progress[:, -1, :][:, traffic].min(axis=1)
    return out


def replay_mix(cfg: StageConfig, mix: TraceMix) -> dict:
    """Replay one multiprogrammed mix; per-app and per-core results.

    Args:
        cfg: the stage configuration; ``cfg.n_sockets`` must match the
            mix's core count (24 cores per socket).
        mix: an unbatched `TraceMix` (`assign_traces`).
    Returns:
        The whole-platform views (scalars keyed by `VIEW_KEYS`) plus
        ``app_runtime_ms`` / ``app_runtime_windows`` / ``app_done``
        arrays indexed by app id, and the per-core
        ``core_runtime_windows`` / ``core_done`` they reduce from.
    """
    batched = jax.tree_util.tree_map(lambda a: a[None], mix)
    out = replay_mixes(cfg, batched)
    return jax.tree_util.tree_map(lambda a: a[0], out)


def replay_mixes(cfg: StageConfig, mixes: TraceMix,
                 donate: bool = False) -> dict:
    """Replay a stack of mixes (leading mix axis, device-sharded).

    Args:
        cfg: the stage configuration (one compiled program).
        mixes: a `TraceMix` batch from `stack_mixes`; all mixes share
            the platform's core count.
        donate: donate the mix buffers to the compiled replay (the
            batch is consumed — see `replay_suite`).
    Returns:
        Host-side dict: views (M,), per-core arrays (M, n_cores), and
        per-app arrays (M, A) where A is the largest app count across
        the batch (`nan` / False padding for mixes with fewer apps).
    """
    # host-side fields first: after a donating call the buffers are gone
    target = np.asarray(jax.device_get(mixes.length))   # (M, n_cores)
    app_id = np.asarray(jax.device_get(mixes.app_id))   # (M, n_cores)
    pos0 = np.asarray(jax.device_get(mixes.pos0))       # (M, n_cores)
    out = _replay_exact(cfg, mixes, donate)
    progress = np.asarray(out.pop("progress"))       # (M, W, n_cores)

    rt, done = _runtime_windows(progress, target, pos0)
    cpu = cfg.platform.cpu
    window_ms = cpu.window_cycles * cpu.cpu_ps_per_clk * 1e-9

    M = app_id.shape[0]
    n_apps = int(app_id.max()) + 1 if app_id.size else 0
    app_rt = np.full((M, n_apps), np.nan)
    app_done = np.zeros((M, n_apps), bool)
    for m in range(M):
        for a in range(n_apps):
            cores = app_id[m] == a
            if cores.any():
                # an app finishes when its slowest core does
                app_rt[m, a] = rt[m, cores].max()
                app_done[m, a] = done[m, cores].all()

    out["core_runtime_windows"] = rt
    out["core_done"] = done
    out["app_runtime_windows"] = app_rt
    out["app_runtime_ms"] = app_rt * window_ms
    out["app_done"] = app_done
    return out


def replay_stages(stages, traces: Trace, preset: str | None = None,
                  **overrides) -> dict:
    """Replay one trace batch across several stages.

    Args:
        stages: iterable of stage names or `StageConfig`s.
        traces: stacked `Trace` batch (leading application axis).
        preset: optional device preset applied to every named stage.
        **overrides: `StageConfig` field overrides applied to every
            named stage (window-count knobs for CI-speed vs full runs,
            ``n_sockets=2`` for a two-socket frontend, ...).
    Returns:
        ``{stage_name: replay_suite(...)}``.
    """
    from repro.core import get_stage

    results = {}
    for st in stages:
        cfg = st if isinstance(st, StageConfig) else get_stage(
            st, preset=preset, **overrides)
        results[cfg.name] = replay_suite(cfg, traces)
    return results


def replay_grid(presets, stages, traces: Trace, **overrides) -> dict:
    """One fleet-scale scenario grid: preset x stage x application.

    Every (preset, stage) cell is one compiled program whose
    application axis is sharded across all devices; presets and stages
    iterate in Python because they change static shapes (channel/bank
    geometry, clock ratios, scheduler policy).  One call covers the
    whole grid.

    Args:
        presets: iterable of device preset names (`repro.core.presets`).
        stages: iterable of stage names.
        traces: stacked `Trace` batch shared by every cell.
        **overrides: `StageConfig` overrides applied to every cell.
    Returns:
        ``{preset: {stage: replay_suite(...)}}``.
    """
    return {p: replay_stages(stages, traces, preset=p, **overrides)
            for p in presets}
