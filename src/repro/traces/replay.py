"""Batched multi-application replay engine.

One compiled program replays a whole application suite: the stacked
`Trace` batch vmaps over `platform.run_frontend`, so N applications
share a single XLA compile per stage (the same pattern `mess.sweep`
uses for pace points).  Stages iterate in Python because they differ in
*static* configuration (clock model, scheduler policy), which changes
program shapes.

Outputs per application:

* the three views (simulator / interface / application bandwidth and
  latency) — the paper's methodology applied to real access patterns;
* a predicted application *runtime*: the window at which the trace was
  fully consumed (or an extrapolation from the final replay rate when
  the configured window count ends first).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.platform import StageConfig, run_frontend
from repro.traces.frontend import TraceFrontend
from repro.traces.trace import Trace

#: per-app result keys that are plain per-window scalars in the views
VIEW_KEYS = ("sim_bw_gbs", "sim_lat_ns", "if_bw_gbs", "if_lat_ns",
             "app_bw_gbs", "app_lat_ns", "chase_lat_ns", "n_rd", "n_wr")


@functools.lru_cache(maxsize=None)
def _replay_fn(cfg: StageConfig):
    """One jit(vmap) program: the app axis is the batch axis."""

    def one(trace: Trace):
        views, outs = run_frontend(cfg, TraceFrontend(
            trace, cfg.workload_config()))
        return dict({k: views[k] for k in VIEW_KEYS},
                    progress=outs.progress)

    return jax.jit(jax.vmap(one))


def replay_suite(cfg: StageConfig, traces: Trace) -> dict:
    """Replay a stacked trace batch through one stage; host-side dict.

    ``traces`` carries a leading application axis (see `stack_traces`).
    Returns numpy arrays keyed by `VIEW_KEYS` plus ``runtime_ms`` /
    ``runtime_windows`` / ``done`` per application.
    """
    out = jax.device_get(_replay_fn(cfg)(traces))
    progress = out.pop("progress")                   # (A, W)
    length = np.asarray(jax.device_get(traces.length))  # (A,)
    out = {k: np.asarray(v) for k, v in out.items()}

    W = progress.shape[1]
    done = progress >= length[:, None]
    any_done = done.any(axis=1)
    first_done = np.where(any_done, done.argmax(axis=1) + 1, W)
    # unfinished apps: extrapolate from the achieved replay rate
    final = np.maximum(progress[:, -1], 1)
    est = W * length / final
    runtime_windows = np.where(any_done, first_done, est)

    cpu = cfg.platform.cpu
    window_ms = cpu.window_cycles * cpu.cpu_ps_per_clk * 1e-9
    out["done"] = any_done
    out["runtime_windows"] = runtime_windows.astype(np.float64)
    out["runtime_ms"] = runtime_windows * window_ms
    out["progress_final"] = progress[:, -1]
    return out


def replay_stages(stages, traces: Trace, **overrides) -> dict:
    """Replay one trace batch across several stages.

    ``stages`` is an iterable of stage names or `StageConfig`s; returns
    ``{stage_name: replay_suite(...)}``.  Window-count overrides apply
    to every stage (CI-speed vs full runs).
    """
    from repro.core import get_stage

    results = {}
    for st in stages:
        cfg = st if isinstance(st, StageConfig) else get_stage(
            st, **overrides)
        results[cfg.name] = replay_suite(cfg, traces)
    return results
