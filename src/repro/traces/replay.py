"""Batched multi-application replay engine (device-sharded).

One compiled program replays a whole application suite: the stacked
`Trace` batch maps over `platform.run_frontend`, with the application
axis sharded across every available device by
`repro.core.shard.sharded_vmap` (bit-identical plain-vmap fallback on
one device), so N applications share a single XLA compile per stage —
the same pattern `mess.sweep` uses for pace points.  Stages and device
presets iterate in Python because they differ in *static*
configuration (clock model, scheduler policy, channel/bank geometry),
which changes program shapes; `replay_grid` wraps that iteration so a
full (preset x stage x app) scenario grid is one invocation.

Outputs per application:

* the three views (simulator / interface / application bandwidth and
  latency) — the paper's methodology applied to real access patterns;
* a predicted application *runtime*: the window at which the trace was
  fully consumed (or an extrapolation from the final replay rate when
  the configured window count ends first).
"""
from __future__ import annotations

import functools

import jax
import numpy as np

from repro.core.platform import StageConfig, run_frontend
from repro.core.shard import sharded_vmap
from repro.traces.frontend import TraceFrontend
from repro.traces.trace import Trace

#: per-app result keys that are plain per-window scalars in the views
VIEW_KEYS = ("sim_bw_gbs", "sim_lat_ns", "if_bw_gbs", "if_lat_ns",
             "app_bw_gbs", "app_lat_ns", "chase_lat_ns", "n_rd", "n_wr")


@functools.lru_cache(maxsize=None)
def _replay_fn(cfg: StageConfig):
    """One compiled program: the app axis is the sharded batch axis."""

    def one(trace: Trace):
        views, outs = run_frontend(cfg, TraceFrontend(
            trace, cfg.workload_config()))
        return dict({k: views[k] for k in VIEW_KEYS},
                    progress=outs.progress)

    return sharded_vmap(one)


def replay_suite(cfg: StageConfig, traces: Trace) -> dict:
    """Replay a stacked trace batch through one stage; host-side dict.

    Args:
        cfg: the stage configuration (clock model, policy, platform).
        traces: a `Trace` with a leading application axis
            (see `stack_traces`); the axis is sharded across devices.
    Returns:
        Numpy arrays keyed by `VIEW_KEYS` (bandwidth GB/s, latency ns)
        plus ``runtime_ms`` / ``runtime_windows`` / ``done`` /
        ``progress_final`` per application.
    """
    out = jax.device_get(_replay_fn(cfg)(traces))
    progress = out.pop("progress")                   # (A, W)
    length = np.asarray(jax.device_get(traces.length))  # (A,)
    out = {k: np.asarray(v) for k, v in out.items()}

    W = progress.shape[1]
    done = progress >= length[:, None]
    any_done = done.any(axis=1)
    first_done = np.where(any_done, done.argmax(axis=1) + 1, W)
    # unfinished apps: extrapolate from the achieved replay rate
    final = np.maximum(progress[:, -1], 1)
    est = W * length / final
    runtime_windows = np.where(any_done, first_done, est)

    cpu = cfg.platform.cpu
    window_ms = cpu.window_cycles * cpu.cpu_ps_per_clk * 1e-9
    out["done"] = any_done
    out["runtime_windows"] = runtime_windows.astype(np.float64)
    out["runtime_ms"] = runtime_windows * window_ms
    out["progress_final"] = progress[:, -1]
    return out


def replay_stages(stages, traces: Trace, preset: str | None = None,
                  **overrides) -> dict:
    """Replay one trace batch across several stages.

    Args:
        stages: iterable of stage names or `StageConfig`s.
        traces: stacked `Trace` batch (leading application axis).
        preset: optional device preset applied to every named stage.
        **overrides: `StageConfig` field overrides applied to every
            named stage (window-count knobs for CI-speed vs full runs).
    Returns:
        ``{stage_name: replay_suite(...)}``.
    """
    from repro.core import get_stage

    results = {}
    for st in stages:
        cfg = st if isinstance(st, StageConfig) else get_stage(
            st, preset=preset, **overrides)
        results[cfg.name] = replay_suite(cfg, traces)
    return results


def replay_grid(presets, stages, traces: Trace, **overrides) -> dict:
    """One fleet-scale scenario grid: preset x stage x application.

    Every (preset, stage) cell is one compiled program whose
    application axis is sharded across all devices; presets and stages
    iterate in Python because they change static shapes (channel/bank
    geometry, clock ratios, scheduler policy).  One call covers the
    whole grid.

    Args:
        presets: iterable of device preset names (`repro.core.presets`).
        stages: iterable of stage names.
        traces: stacked `Trace` batch shared by every cell.
        **overrides: `StageConfig` overrides applied to every cell.
    Returns:
        ``{preset: {stage: replay_suite(...)}}``.
    """
    return {p: replay_stages(stages, traces, preset=p, **overrides)
            for p in presets}
