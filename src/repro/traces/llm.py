"""LLM inference traffic lowered onto the memory platform.

The "serve the planet" workload: per-decode-step HLO memory traffic —
weight streaming, KV-cache reads/writes under continuous batching —
derived from the model configs (`repro.configs`, tinyllama_1_1b
through arctic_480b) and lowered into `repro.traces.Trace` streams
that replay through the DDR4/DDR5/HBM presets.

The pipeline has three stages:

1. **Render + cost** (`decode_hlo`, `decode_cost`).  For a model
   config at a given pool size and context length, render an
   HLO-shaped text module for ONE continuous-batching decode step —
   an embedding gather, a trip-counted while loop over the stacked
   layers (weights consumed through fused dynamic-slices, exactly how
   a scan-over-layers decode lowers), attention score/context dots
   over the KV cache, dynamic-update-slice cache appends, and the
   vocab-projection dot — and run it through
   `repro.perfmodel.hlo_cost.analyze`.  The renderer mirrors the cost
   model's byte accounting per op, tagged by traffic stream (weights
   / kv_read / kv_write / activations); `decode_cost` *raises* if the
   mirrored total ever disagrees with `analyze`, so the lowering can
   never drift from the HLO cost model it claims to consume.  The
   text targets `analyze`'s grammar (it is not XLA-round-trippable).
2. **Schedule** (`simulate_schedule`).  A host-side continuous-
   batching scheduler built on the *same* `repro.serve.engine.SlotPool`
   admission the model engine uses: requests arrive by a Poisson /
   uniform / burst process, are admitted FIFO into a fixed slot pool,
   force-feed their prompt one token per step, then decode; finished
   slots recycle.  The schedule records per-step occupancy and
   per-slot context — the drivers of per-step memory traffic.
3. **Lower** (`lower_decode`, `lower_serving`, `lower_scenario`).
   Byte totals become line-granular accesses.  Real per-step traffic
   is GBs (tens of millions of lines), so the trace models a 1/shard
   slice (one channel/device's share); ``shard`` is returned so byte
   conservation can be checked exactly: per traffic stream, emitted
   lines are the floor of the exact running byte total over the
   quantum ``shard x line_bytes`` (largest-remainder carry), so the
   whole trace conserves bytes to within one line per stream.
   Per-step stream bytes for the serving trace come from an exact
   bilinear model ``c0 + c_n * n_active + c_t * sum(ctx)`` fitted
   from three `decode_cost` anchor evaluations — exact, not
   approximate, because every rendered byte term is linear in the
   pool size and in pool-size x context (`serving_terms`).

Address layout is compact and regional: a weights region re-walked
sequentially every step (streamed layer weights), a KV region scanned
by reads with appends at the write cursor, and a small activation
region — so row locality and read/write mix are representative while
footprints stay far below the 2^31-line traffic address space.

Per-request latency under memory contention comes out the other side:
replay the lowered trace (`repro.traces.replay.replay_suite`) with
``telemetry=True`` and feed the interface-latency histograms to
`repro.obs.hist_percentiles` (p50/p95/p99), and convert scheduler
steps to milliseconds with `request_latencies_ms` — see
`benchmarks/serving.py` and docs/SERVING.md.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

from repro.models.common import ModelConfig
from repro.perfmodel import hlo_cost
from repro.perfmodel.hlo import DTYPE_BYTES
from repro.serve.engine import SlotPool
from repro.traces.trace import Trace, make_trace

#: traffic streams, in per-step emission order
STREAMS = ("weights", "kv_read", "act_rd", "act_wr", "kv_write")
#: reported phases (activation reads+writes fold into one)
PHASES = ("weights", "kv_read", "kv_write", "act")

_DT_NAMES = {jnp.bfloat16: "bf16", jnp.float32: "f32", jnp.float16: "f16"}


def _t(dt: str, dims=()) -> str:
    return f"{dt}[{','.join(str(d) for d in dims)}]"


def _nbytes(arr) -> int:
    dt, dims = arr
    n = 1
    for d in dims:
        n *= int(d)
    return n * DTYPE_BYTES[dt]


class _Module:
    """Accumulates rendered computation blocks + a unique-name counter.

    Every ``%name`` in the module is globally unique (the cost model
    keeps ONE module-wide name -> type map)."""

    def __init__(self):
        self.blocks: list[str] = []
        self._uid = 0

    def uid(self) -> int:
        self._uid += 1
        return self._uid

    def render(self) -> str:
        return "\n".join(self.blocks) + "\n"


class _Comp:
    """One computation (entry or while body) under construction.

    Emits op lines in `hlo_cost.analyze`'s grammar and mirrors its
    byte/flop accounting per op as ``(stream, bytes)`` contributions
    in emission order.  References are ``(name, (dtype, dims))``
    pairs; operand bytes always come from the reference's own array
    (matching the cost model's name->type lookup)."""

    def __init__(self, mod: _Module, name: str):
        self.mod = mod
        self.name = name
        self.cond_name = None
        self.lines: list[str] = []
        self.nparam = 0
        self.contribs: list[tuple] = []     # (stream, bytes)
        self.flops = 0.0

    def nm(self, tag: str = "n") -> str:
        return f"{tag}{self.mod.uid()}"

    # -- ops ---------------------------------------------------------------

    def param(self, arr):
        n = self.nm("p")
        self.lines.append(f"  %{n} = {_t(*arr)} parameter({self.nparam})")
        self.nparam += 1
        return (n, arr)

    def gather(self, out, table, idx, rd="weights", wr="act_wr"):
        """Slice-family read: charged 2 x out (read slice + write out)."""
        n = self.nm("g")
        self.lines.append(
            f"  %{n} = {_t(*out)} gather({_t(*table[1])} %{table[0]}, "
            f"{_t(*idx[1])} %{idx[0]}), offset_dims={{1}}")
        b = _nbytes(out)
        self.contribs += [(rd, b), (wr, b)]
        return (n, out)

    def dot(self, out, lhs, rhs, lcd: int, rcd: int,
            s_out="act_wr", s_lhs="act_rd", s_rhs="act_rd"):
        n = self.nm("d")
        self.lines.append(
            f"  %{n} = {_t(*out)} dot({_t(*lhs[1])} %{lhs[0]}, "
            f"{_t(*rhs[1])} %{rhs[0]}), lhs_contracting_dims={{{lcd}}}, "
            f"rhs_contracting_dims={{{rcd}}}")
        self.contribs += [(s_out, _nbytes(out)), (s_lhs, _nbytes(lhs[1])),
                          (s_rhs, _nbytes(rhs[1]))]
        oe = 1
        for d in out[1]:
            oe *= d
        self.flops += 2.0 * oe * lhs[1][1][lcd]
        return (n, out)

    def fusion(self, out, act, slices, idx,
               s_out="act_wr", s_act="act_rd", s_w="weights"):
        """A fused op streaming stacked per-layer weights.

        ``slices`` is a list of ``(stacked_arr, slice_arr)``: each
        stacked weight parameter is consumed by exactly one
        dynamic-slice inside the fusion computation, so the cost
        model's `_fusion_param_charges` charges the per-layer slice,
        not the whole stack — the weight-streaming accounting.
        """
        if len(slices) > 9:
            raise ValueError("fusion supports at most 9 weight slices "
                             "(names must stay prefix-distinct)")
        u = self.mod.uid()
        fname = f"f{u}"
        # fusion computation: params (prefix-distinct names), one
        # dynamic-slice per weight param, a root referencing only the
        # slice results (never the weight params — a second textual
        # use would void the slice charge)
        fl = [f"  %{fname}a = {_t(*act[1])} parameter(0)"]
        for i, (stk, _) in enumerate(slices):
            fl.append(f"  %{fname}w{i} = {_t(*stk)} parameter({i + 1})")
        fl.append(f"  %{fname}i = s32[] parameter({len(slices) + 1})")
        for i, (stk, sl) in enumerate(slices):
            sizes = ",".join(str(d) for d in sl[1])
            fl.append(
                f"  %{fname}s{i} = {_t(*sl)} dynamic-slice({_t(*stk)} "
                f"%{fname}w{i}, s32[] %{fname}i), "
                f"dynamic_slice_sizes={{{sizes}}}")
        s0 = f"{fname}s0"
        fl.append(f"  ROOT %{fname}r = {_t(*out)} add({_t(*slices[0][1])} "
                  f"%{s0}, {_t(*slices[0][1])} %{s0})")
        self.mod.blocks.append(
            f"%{fname} (h{u}a: s32[]) -> {_t(*out)} {{\n"
            + "\n".join(fl) + "\n}")
        # callsite: operand order matches the fusion's param indices
        wps = [self.param(stk) for stk, _ in slices]
        ip = self.param(("s32", ()))
        n = self.nm("fo")
        ops = ", ".join(
            [f"{_t(*act[1])} %{act[0]}"]
            + [f"{_t(*p[1])} %{p[0]}" for p in wps]
            + [f"s32[] %{ip[0]}"])
        self.lines.append(f"  %{n} = {_t(*out)} fusion({ops}), "
                          f"kind=kLoop, calls=%{fname}")
        self.contribs += [(s_out, _nbytes(out)), (s_act, _nbytes(act[1]))]
        self.contribs += [(s_w, _nbytes(sl)) for _, sl in slices]
        self.contribs.append((s_w, _nbytes(ip[1])))      # the s32 index
        _ = idx   # loop index is rendered per-fusion (kept for clarity)
        return (n, out)

    def dus(self, full, update, idx, stream="kv_write"):
        """dynamic-update-slice: charged 2 x update (read + write)."""
        n = self.nm("u")
        self.lines.append(
            f"  %{n} = {_t(*full[1])} dynamic-update-slice("
            f"{_t(*full[1])} %{full[0]}, {_t(*update[1])} %{update[0]}, "
            f"s32[] %{idx[0]})")
        self.contribs.append((stream, 2 * _nbytes(update[1])))
        return (n, full[1])

    def while_loop(self, body: "_Comp", trip: int, x):
        """Glue a finalized while body into this computation."""
        tt = f"({_t(*x[1])})"
        tup, wl, gte = self.nm(), self.nm(), self.nm()
        self.lines.append(f"  %{tup} = {tt} tuple({_t(*x[1])} %{x[0]})")
        self.lines.append(
            f"  %{wl} = {tt} while({tt} %{tup}), "
            f"condition=%{body.cond_name}, body=%{body.name}")
        self.lines.append(f"  %{gte} = {_t(*x[1])} "
                          f"get-tuple-element({tt} %{wl}), index=0")
        return (gte, x[1])

    # -- finalize ----------------------------------------------------------

    def finalize_body(self, trip: int, carry, last_ref):
        """Close a while body: root tuple + matching cond block."""
        tt = f"({_t(*carry)})"
        rn = self.nm()
        self.lines.append(f"  ROOT %{rn} = {tt} tuple("
                          f"{_t(*carry)} %{last_ref[0]})")
        u = self.mod.uid()
        self.cond_name = f"c{u}"
        kn, rn2 = f"k{self.mod.uid()}", f"k{self.mod.uid()}"
        self.mod.blocks.append(
            f"%{self.cond_name} (h{u}c: s32[]) -> pred[] {{\n"
            f"  %{kn} = s32[] constant({trip})\n"
            f"  ROOT %{rn2} = pred[] compare(s32[] %{kn}, s32[] %{kn}), "
            f"direction=LT\n}}")
        self.mod.blocks.append(
            f"%{self.name} (h{u}b: s32[]) -> {tt} {{\n"
            + "\n".join(self.lines) + "\n}")

    def finalize_entry(self, ret):
        self.lines[-1] = self.lines[-1].replace("  %", "  ROOT %", 1)
        u = self.mod.uid()
        self.mod.blocks.append(
            f"ENTRY %{self.name} (h{u}e: s32[]) -> {_t(*ret)} {{\n"
            + "\n".join(self.lines) + "\n}")


# ---------------------------------------------------------------- blocks

def _attn_block(c: _Comp, x, idx, *, B, S, D, HQ, HKV, DH, NL, dt,
                write=True, qkv=True):
    """Decode attention over a (B, S, HKV, DH) cache.

    Weights stream through a fused dynamic-slice (QKV + output
    projections, or Q + output only for cross-attention); the score
    and context dots read the K and V caches once each; the update
    writes one (B, 1, HKV, DH) row per cache (skipped for
    cross-attention, whose context is precomputed).
    """
    G = max(1, HQ // HKV)
    HQD = HQ * DH
    ind = (HQ + 2 * HKV) * DH if qkv else HQD
    q = c.fusion((dt, (B, HKV, G, DH)), x,
                 [((dt, (NL, D, ind)), (dt, (1, D, ind))),
                  ((dt, (NL, HQD, D)), (dt, (1, HQD, D)))], idx)
    kc = c.param((dt, (B, S, HKV, DH)))
    vc = c.param((dt, (B, S, HKV, DH)))
    sc = c.dot((dt, (B, HKV, G, S)), q, kc, 3, 3, s_rhs="kv_read")
    ctx = c.dot((dt, (B, HKV, G, DH)), sc, vc, 3, 1, s_rhs="kv_read")
    if write:
        kf = c.param((dt, (B, S + 1, HKV, DH)))
        vf = c.param((dt, (B, S + 1, HKV, DH)))
        kn = c.param((dt, (B, 1, HKV, DH)))
        vn = c.param((dt, (B, 1, HKV, DH)))
        c.dus(kf, kn, idx)
        c.dus(vf, vn, idx)
    return ctx


def _ffn_block(c: _Comp, x, idx, cfg: ModelConfig, *, B, NL, dt):
    D, F = cfg.d_model, cfg.d_ff
    if F == 0:
        return x
    if cfg.family == "moe" or (cfg.family == "hybrid" and cfg.n_experts):
        E, TK = cfg.n_experts, cfg.top_k
        slices = [((dt, (NL, D, E)), (dt, (1, D, E))),          # router
                  ((dt, (NL, E, D, F)), (dt, (1, TK, D, F))),   # gate
                  ((dt, (NL, E, D, F)), (dt, (1, TK, D, F))),   # up
                  ((dt, (NL, E, F, D)), (dt, (1, TK, F, D)))]   # down
        if cfg.dense_residual:
            slices += [((dt, (NL, D, 2 * F)), (dt, (1, D, 2 * F))),
                       ((dt, (NL, F, D)), (dt, (1, F, D)))]
    else:
        slices = [((dt, (NL, D, 2 * F)), (dt, (1, D, 2 * F))),  # gate+up
                  ((dt, (NL, F, D)), (dt, (1, F, D)))]          # down
    return c.fusion((dt, (B, D)), x, slices, idx)


def _ssm_block(c: _Comp, x, idx, cfg: ModelConfig, *, B, NL, dt):
    """Recurrent-state layer (mamba2 / xlstm): in/out projections
    stream like weights; the state — O(1) in sequence length — is
    gathered (read) and written back whole each step."""
    D, DI = cfg.d_model, cfg.d_inner
    se = DI * cfg.ssm_state + DI * cfg.conv_kernel    # SSD + conv state
    h = c.fusion((dt, (B, DI)), x,
                 [((dt, (NL, D, 2 * DI)), (dt, (1, D, 2 * DI))),
                  ((dt, (NL, DI, D)), (dt, (1, DI, D)))], idx)
    st = c.param((dt, (NL, B, se)))
    c.gather((dt, (B, se)), st, idx, rd="kv_read", wr="act_wr")
    up = c.param((dt, (1, B, se)))
    c.dus(st, up, idx)
    return h


def _render(cfg: ModelConfig, batch: int, ctx_len: int):
    """Render the decode-step module; returns ``(text, prog)``.

    ``prog`` is ``(pre, loops, post, flops)``: entry contributions
    before/after the layer loops and ``[(trip, body_contribs)]`` per
    while loop, all in emission order.
    """
    if batch < 1 or ctx_len < 1:
        raise ValueError(f"batch/ctx_len must be >= 1, got "
                         f"{batch}/{ctx_len}")
    B, S = int(batch), int(ctx_len)
    D, V, NL = cfg.d_model, cfg.vocab, cfg.n_layers
    HQ, HKV, DH = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = _DT_NAMES.get(cfg.dtype, "bf16")

    mod = _Module()
    e = _Comp(mod, "serve_decode")
    tok = e.param(("s32", (B, 1)))
    emb = e.param((dt, (V, D)))
    x = e.gather((dt, (B, D)), emb, tok, rd="weights", wr="act_wr")
    pre = list(e.contribs)
    e.contribs = []

    def body(fill):
        b = _Comp(mod, f"b{mod.uid()}")
        bx = b.param((dt, (B, D)))
        bi = b.param(("s32", ()))
        out = fill(b, bx, bi)
        return b, out

    loops = []
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        def self_layers(b, bx, bi):
            h = _attn_block(b, bx, bi, B=B, S=S, D=D, HQ=HQ, HKV=HKV,
                            DH=DH, NL=NL, dt=dt)
            if cfg.family == "audio":
                # enc-dec cross-attention every decoder layer
                h = _attn_block(b, h, bi, B=B, S=cfg.n_ctx_tokens, D=D,
                                HQ=HQ, HKV=HKV, DH=DH, NL=NL, dt=dt,
                                write=False, qkv=False)
            return _ffn_block(b, h, bi, cfg, B=B, NL=NL, dt=dt)
        loops.append((NL, body(self_layers)))
        if cfg.family == "vlm" and cfg.cross_attn_every:
            nc = -(-NL // cfg.cross_attn_every)
            loops.append((nc, body(lambda b, bx, bi: _attn_block(
                b, bx, bi, B=B, S=cfg.n_ctx_tokens, D=D, HQ=HQ, HKV=HKV,
                DH=DH, NL=nc, dt=dt, write=False, qkv=False))))
    elif cfg.family == "ssm":
        loops.append((NL, body(lambda b, bx, bi: _ssm_block(
            b, bx, bi, cfg, B=B, NL=NL, dt=dt))))
    elif cfg.family == "hybrid":
        loops.append((NL, body(lambda b, bx, bi: _ssm_block(
            b, bx, bi, cfg, B=B, NL=NL, dt=dt))))
        nsh = -(-NL // cfg.attn_every)

        def shared(b, bx, bi):
            h = _attn_block(b, bx, bi, B=B, S=S, D=D, HQ=HQ, HKV=HKV,
                            DH=DH, NL=nsh, dt=dt)
            return _ffn_block(b, h, bi, cfg, B=B, NL=nsh, dt=dt)
        loops.append((nsh, body(shared)))
    else:
        raise ValueError(f"unknown model family {cfg.family!r}")

    loop_contribs, flops = [], 0.0
    for trip, (b, out) in loops:
        b.finalize_body(trip, (dt, (B, D)), out)
        x = e.while_loop(b, trip, x)
        loop_contribs.append((trip, b.contribs))
        flops += trip * b.flops

    head = e.param((dt, (D, V)))
    e.dot((dt, (B, V)), x, head, 1, 0, s_rhs="weights")
    post = list(e.contribs)
    flops += e.flops
    e.finalize_entry((dt, (B, V)))
    return mod.render(), (pre, loop_contribs, post, flops)


# ------------------------------------------------------------------ cost

def decode_hlo(cfg: ModelConfig, batch: int, ctx_len: int) -> str:
    """The rendered decode-step HLO text (for inspection / analyze)."""
    return _render(cfg, batch, ctx_len)[0]


def decode_cost(cfg: ModelConfig, batch: int, ctx_len: int) -> dict:
    """Per-decode-step traffic from `hlo_cost.analyze`, by stream.

    Renders the module, analyzes it, and cross-checks the renderer's
    mirrored accounting against the cost model *exactly* — a drifted
    total raises, so the lowering provably consumes `analyze`'s
    numbers.  Returns ``bytes`` / ``flops`` (analyze's trip-scaled
    totals), ``stream_bytes`` / ``phase_bytes`` splits, and
    ``ordered`` — the flattened ``(stream, bytes)`` contributions of
    one decode step in program order (layer loops unrolled).
    """
    text, (pre, loops, post, flops) = _render(cfg, batch, ctx_len)
    got = hlo_cost.analyze(text)
    ordered = list(pre)
    for trip, contribs in loops:
        ordered.extend(contribs * trip)
    ordered.extend(post)
    mine = sum(b for _, b in ordered)
    if int(got["bytes"]) != int(mine):
        raise AssertionError(
            f"renderer/cost-model drift: analyze says {got['bytes']:.0f} "
            f"bytes, mirrored accounting says {mine} "
            f"({cfg.name}, B={batch}, S={ctx_len})")
    if abs(got["flops"] - flops) > 0.5:
        raise AssertionError(
            f"renderer/cost-model flop drift: {got['flops']} vs {flops}")
    stream_bytes = {s: 0 for s in STREAMS}
    for s, b in ordered:
        stream_bytes[s] += b
    phase_bytes = dict(weights=stream_bytes["weights"],
                       kv_read=stream_bytes["kv_read"],
                       kv_write=stream_bytes["kv_write"],
                       act=stream_bytes["act_rd"] + stream_bytes["act_wr"])
    return dict(bytes=int(mine), flops=float(got["flops"]),
                stream_bytes=stream_bytes, phase_bytes=phase_bytes,
                ordered=ordered)


# ------------------------------------------------------------- lowering

class _AddrGen:
    """Regional line-address generator with per-stream byte carries.

    One emitted line stands for ``quantum = shard x line_bytes`` bytes;
    per stream, line counts are the floor-difference of the exact
    cumulative byte total (largest-remainder), so the emitted trace
    conserves modeled bytes to within one line per stream.
    """

    def __init__(self, quantum: int, w_lines: int, kv_lines: int,
                 act_lines: int):
        self.q = quantum
        self.w0, self.wsz = 0, max(64, w_lines)
        self.k0, self.ksz = self.wsz, max(64, kv_lines)
        self.a0, self.asz = self.wsz + self.ksz, max(64, act_lines)
        self.footprint = self.wsz + self.ksz + self.asz
        self.carry = dict.fromkeys(STREAMS, 0)
        self.cur = dict(weights=0, kv_rd=0, kv_wr=0, act=0)
        self.lines: list[np.ndarray] = []
        self.wr: list[np.ndarray] = []

    def new_step(self):
        """Weights and activations re-walk their regions every step."""
        self.cur["weights"] = 0
        self.cur["act"] = 0

    def _take(self, stream: str, nbytes: int) -> int:
        c = self.carry[stream] + int(nbytes)
        n, self.carry[stream] = divmod(c, self.q)
        return n

    def emit(self, stream: str, nbytes: int):
        n = self._take(stream, nbytes)
        if n == 0:
            return
        if stream == "weights":
            base, sz, cur, w = self.w0, self.wsz, "weights", 0
        elif stream == "kv_read":
            base, sz, cur, w = self.k0, self.ksz, "kv_rd", 0
        elif stream == "kv_write":
            # RMW pairs: read + write of each appended line
            k = (n + 1) // 2
            ln = self.k0 + (self.cur["kv_wr"]
                            + np.repeat(np.arange(k), 2)[:n]) % self.ksz
            self.cur["kv_wr"] += k
            self.lines.append(ln.astype(np.int64))
            self.wr.append((np.arange(n) % 2).astype(np.int32))
            return
        else:                                   # act_rd / act_wr
            base, sz, cur, w = self.a0, self.asz, "act", \
                (1 if stream == "act_wr" else 0)
        ln = base + (self.cur[cur] + np.arange(n)) % sz
        self.cur[cur] += n
        self.lines.append(ln.astype(np.int64))
        self.wr.append(np.full(n, w, np.int32))

    def trace(self) -> Trace:
        lines = (np.concatenate(self.lines) if self.lines
                 else np.zeros(0, np.int64))
        if lines.size == 0:
            raise ValueError("lowering produced an empty trace "
                             "(raise target_lines or traffic)")
        wr = np.concatenate(self.wr)
        delta = np.diff(lines, prepend=0).astype(np.int32)
        return make_trace(delta, wr, np.zeros_like(wr), self.footprint)


def _region_lines(q, w_bytes, kv_bytes, act_bytes):
    cap = 1 << 20
    r = lambda b: min(cap, math.ceil(max(b, 1) / q))
    return r(w_bytes), r(kv_bytes), r(act_bytes)


def lower_decode(cfg: ModelConfig, batch: int, ctx_len: int, *,
                 steps: int = 1, target_lines: int = 4096,
                 line_bytes: int = 64):
    """Lower ``steps`` decode steps to a `Trace`; ``(trace, info)``.

    ``info['shard']`` is the byte-to-line scale: the trace models a
    1/shard slice of the step's traffic such that the whole lowering
    stays near ``target_lines`` accesses.  Conservation:
    ``accesses x line_bytes x shard`` equals
    ``decode_cost(...)['bytes'] x steps`` to within
    ``len(STREAMS) x line_bytes x shard``.
    """
    if target_lines < 64:
        raise ValueError("target_lines must be >= 64")
    cost = decode_cost(cfg, batch, ctx_len)
    total = cost["bytes"] * steps
    shard = max(1, math.ceil(total / (line_bytes * target_lines)))
    q = shard * line_bytes
    sb = cost["stream_bytes"]
    gen = _AddrGen(q, *_region_lines(
        q, sb["weights"],
        sb["kv_read"] + sb["kv_write"] * steps,
        sb["act_rd"] + sb["act_wr"]))
    for _ in range(steps):
        gen.new_step()
        for s, b in cost["ordered"]:
            gen.emit(s, b)
    trace = gen.trace()
    info = dict(shard=shard, line_bytes=line_bytes,
                accesses=int(trace.length), bytes_modeled=int(total),
                footprint_lines=gen.footprint,
                stream_bytes={k: int(v * steps) for k, v in sb.items()},
                phase_bytes={k: int(v * steps)
                             for k, v in cost["phase_bytes"].items()})
    return trace, info


# ----------------------------------------------------------- scheduling

@dataclasses.dataclass(frozen=True)
class ServeScenario:
    """One serving cell: a model under an arrival process."""

    model: ModelConfig
    arrival: str = "poisson"        # poisson | uniform | burst
    rate: float = 0.5               # mean request arrivals per step
    n_requests: int = 16
    n_slots: int = 4
    prompt_mean: int = 8
    decode_mean: int = 16
    seed: int = 0


@dataclasses.dataclass
class ServeRequest:
    rid: int
    arrival: int                    # step the request arrived
    total: int                      # prompt + decode tokens
    pos: int = 0                    # tokens in cache (context)
    admit: int = -1                 # step admitted into a slot
    finish: int = -1                # step the last token was produced


@dataclasses.dataclass
class ServeSchedule:
    """Per-step occupancy profile + per-request lifecycle."""

    scenario: ServeScenario
    n_active: np.ndarray            # (T,) slots busy per step
    ctx_sum: np.ndarray             # (T,) sum of per-slot context
    requests: list

    @property
    def steps(self) -> int:
        return len(self.n_active)

    @property
    def latency_steps(self) -> np.ndarray:
        """Per-request arrival-to-completion, scheduler steps."""
        return np.asarray([r.finish - r.arrival + 1 for r in self.requests])

    @property
    def queue_delay_steps(self) -> np.ndarray:
        return np.asarray([r.admit - r.arrival for r in self.requests])


def arrival_steps(scn: ServeScenario) -> np.ndarray:
    """Deterministic arrival process: request -> arrival step."""
    if scn.rate <= 0:
        raise ValueError(f"rate must be > 0, got {scn.rate}")
    rng = np.random.default_rng(scn.seed)
    n = scn.n_requests
    if scn.arrival == "poisson":
        gaps = rng.exponential(1.0 / scn.rate, size=n)
        return np.floor(np.cumsum(gaps)).astype(np.int64)
    if scn.arrival == "uniform":
        return np.floor(np.arange(n) / scn.rate).astype(np.int64)
    if scn.arrival == "burst":
        return np.zeros(n, np.int64)
    raise ValueError(f"unknown arrival process {scn.arrival!r}")


def simulate_schedule(scn: ServeScenario,
                      max_steps: int = 100_000) -> ServeSchedule:
    """Continuous batching on `repro.serve.engine.SlotPool` admission.

    One step = one pooled decode tick (the `Engine` semantics: a
    prompt token force-feeds, a decode token is produced — either way
    the slot's context grows by one).  Finished slots recycle
    immediately; arrivals queue FIFO.
    """
    rng = np.random.default_rng(scn.seed + 1)
    arr = arrival_steps(scn)
    reqs = [ServeRequest(
        rid=i, arrival=int(arr[i]),
        total=max(1, int(rng.poisson(scn.prompt_mean)))
        + max(1, int(rng.poisson(scn.decode_mean))))
        for i in range(scn.n_requests)]
    pool = SlotPool(scn.n_slots)
    t, i = 0, 0
    n_active, ctx_sum = [], []
    while i < len(reqs) or pool.pending():
        while i < len(reqs) and reqs[i].arrival <= t:
            pool.submit(reqs[i])
            i += 1
        for _, r in pool.admit():
            r.admit = t
        act = pool.active()
        n_active.append(len(act))
        ctx_sum.append(sum(r.pos for _, r in act))
        for s, r in act:
            r.pos += 1
            if r.pos >= r.total:
                r.finish = t
                pool.free(s)
        t += 1
        if t >= max_steps:
            raise RuntimeError(
                f"schedule did not drain in {max_steps} steps")
    return ServeSchedule(scenario=scn,
                         n_active=np.asarray(n_active, np.int64),
                         ctx_sum=np.asarray(ctx_sum, np.int64),
                         requests=reqs)


# ----------------------------------------------------- serving lowering

def serving_terms(model: ModelConfig) -> dict:
    """Exact per-step traffic model ``c0 + c_n * n + c_t * ctx_sum``.

    Every rendered byte term is linear in the pool size ``B`` and in
    ``B x S`` (weights constant, activations per-slot, KV per
    slot-token), so three `decode_cost` anchor evaluations determine
    the per-stream coefficients exactly — the scheduler's per-step
    traffic *is* the HLO cost model's, evaluated at that step's
    occupancy.
    """
    B0, S0, S1 = 4, 32, 96
    p00 = decode_cost(model, B0, S0)["stream_bytes"]
    p01 = decode_cost(model, B0, S1)["stream_bytes"]
    p10 = decode_cost(model, 1, S0)["stream_bytes"]
    terms = {}
    for s in STREAMS:
        ct = (p01[s] - p00[s]) / (B0 * (S1 - S0))
        cn = (p00[s] - p10[s] - ct * (B0 - 1) * S0) / (B0 - 1)
        c0 = p10[s] - cn - ct * S0
        terms[s] = (c0, cn, ct)
    return terms


def step_stream_bytes(terms: dict, n_active: int, ctx_sum: int) -> dict:
    """Evaluate the bilinear traffic model at one scheduler step."""
    if n_active <= 0:
        return {s: 0 for s in STREAMS}
    return {s: int(round(max(0.0, c0 + cn * n_active + ct * ctx_sum)))
            for s, (c0, cn, ct) in terms.items()}


def lower_serving(model: ModelConfig, sched: ServeSchedule, *,
                  target_step_lines: int = 512, line_bytes: int = 64):
    """Lower a whole serving schedule to one `Trace`; ``(trace, info)``.

    Each scheduler step contributes its modeled stream bytes (from
    `serving_terms`) in weights -> kv_read -> act -> kv_write order;
    ``info['cum_bytes']`` maps steps to cumulative traffic so replay
    runtime converts to per-request latency (`request_latencies_ms`).
    """
    terms = serving_terms(model)
    per_step = [step_stream_bytes(terms, int(n), int(c))
                for n, c in zip(sched.n_active, sched.ctx_sum)]
    step_tot = np.asarray([sum(p.values()) for p in per_step], np.int64)
    if step_tot.sum() == 0:
        raise ValueError("schedule generated no traffic")
    shard = max(1, math.ceil(step_tot.max()
                             / (line_bytes * target_step_lines)))
    q = shard * line_bytes
    gen = _AddrGen(q, *_region_lines(
        q, max(p["weights"] for p in per_step),
        max(p["kv_read"] for p in per_step)
        + sum(p["kv_write"] for p in per_step),
        max(p["act_rd"] + p["act_wr"] for p in per_step)))
    for p in per_step:
        gen.new_step()
        for s in STREAMS:
            gen.emit(s, p[s])
    trace = gen.trace()
    tot = {s: int(sum(p[s] for p in per_step)) for s in STREAMS}
    info = dict(shard=shard, line_bytes=line_bytes,
                accesses=int(trace.length),
                bytes_modeled=int(step_tot.sum()),
                footprint_lines=gen.footprint,
                stream_bytes=tot,
                phase_bytes=dict(weights=tot["weights"],
                                 kv_read=tot["kv_read"],
                                 kv_write=tot["kv_write"],
                                 act=tot["act_rd"] + tot["act_wr"]),
                step_bytes=step_tot,
                cum_bytes=np.cumsum(step_tot))
    return trace, info


def lower_scenario(scn: ServeScenario, **kw):
    """Scenario -> schedule -> trace; ``(trace, sched, info)``."""
    sched = simulate_schedule(scn)
    trace, info = lower_serving(scn.model, sched, **kw)
    return trace, sched, info


def request_latencies_ms(sched: ServeSchedule, info: dict,
                         runtime_ms: float) -> np.ndarray:
    """Per-request latency under memory contention, milliseconds.

    The replayed ``runtime_ms`` (from `replay_suite`) is the service
    time of the whole schedule's traffic; each step's share is its
    byte fraction, so a request's latency is the service time of
    every step in its arrival..finish span — queueing delay priced at
    the platform's actual (contended) service rate.
    """
    cum = np.concatenate([[0], np.asarray(info["cum_bytes"], np.float64)])
    total = cum[-1]
    t_ms = runtime_ms * cum / total
    return np.asarray([t_ms[r.finish + 1] - t_ms[r.arrival]
                       for r in sched.requests])
