"""DAMOV-style synthetic application kernels as trace generators.

Each generator is a pure function ``(n_accesses, footprint_lines, seed)
-> Trace`` emitting the per-access line-delta / write-flag / dependency
stream of one application class from the DAMOV taxonomy:

* ``stream``        — DRAM-bandwidth-bound streaming (STREAM triad):
                      unit-stride, 2 reads : 1 write, no dependencies.
* ``gups``          — random-access update (HPCC RandomAccess): uniform
                      random lines, read-modify-write pairs.
* ``stencil3d``     — 7-point 3-D stencil sweep: unit stride plus plane
                      /row neighbour strides, 7 reads : 1 write.
* ``spmv``          — sparse matrix-vector product (CSR): streaming row
                      and column-index reads interleaved with irregular
                      gathers of the dense vector.
* ``pointer_chase`` — linked-list traversal: every access depends on
                      the previous one (latency-bound by construction).
* ``bfs_frontier``  — BFS frontier expansion: streaming frontier reads,
                      each followed by a dependent burst of irregular
                      neighbour reads (mixed latency/bandwidth).
* ``mess_traffic``  — the Mess traffic-generator pattern itself
                      (64-line sequential segments at random bases) as a
                      trace, used to cross-validate the trace frontend
                      against the native pace generator on identical
                      traffic.

Generation is host-side numpy (deterministic PCG64 per kernel+seed);
the emitted `Trace` is the JAX-native object the replay engine batches.
Kernels registered in `KERNELS` are picked up by the validation
benchmarks and can be combined into multiprogrammed per-core mixes
(`repro.traces.mix.assign_traces`; `benchmarks/app_validation.py`
``MIXES``) — docs/WORKLOADS.md walks through authoring a new one.
"""
from __future__ import annotations

import zlib

import numpy as np

from repro.core.workload import SEGMENT_LINES
from repro.traces.trace import Trace, make_trace

DEFAULT_FOOTPRINT = 1 << 20          # 64 MB per core (1 Mi lines)


def _rng(name: str, seed: int) -> np.random.Generator:
    # stable across processes (hash() is salted per interpreter run)
    return np.random.Generator(np.random.PCG64(
        np.random.SeedSequence([seed, zlib.crc32(name.encode())])))


def _to_trace(lines, is_write, dep, footprint: int) -> Trace:
    lines = np.asarray(lines, np.int64) % footprint
    delta = np.diff(lines, prepend=0).astype(np.int32)
    return make_trace(delta, is_write, dep, footprint)


def stream(n: int = 4096, footprint: int = DEFAULT_FOOTPRINT,
           seed: int = 0) -> Trace:
    """STREAM triad: a[i] = b[i] + s*c[i] — 2 streaming reads, 1 write."""
    i = np.arange(n)
    elem = i // 3
    which = i % 3                     # 0: read b, 1: read c, 2: write a
    lines = (which * (footprint // 3) + elem) % footprint
    return _to_trace(lines, which == 2, np.zeros(n), footprint)


def gups(n: int = 4096, footprint: int = DEFAULT_FOOTPRINT,
         seed: int = 0) -> Trace:
    """Random-access updates: read a random line, write it back."""
    r = _rng("gups", seed)
    target = r.integers(0, footprint, size=(n + 1) // 2)
    lines = np.repeat(target, 2)[:n]
    m = lines.shape[0]
    is_write = (np.arange(m) % 2).astype(np.int32)   # read then write
    return _to_trace(lines, is_write, np.zeros(m), footprint)


def stencil3d(n: int = 4096, footprint: int = DEFAULT_FOOTPRINT,
              seed: int = 0) -> Trace:
    """7-point stencil over an nx*ny*nz grid (one line per 8 points)."""
    nx = max(int(round(footprint ** (1 / 3))), 4)
    ny, nz = nx, max(footprint // (nx * nx), 1)
    pts = n // 8
    i = np.arange(pts)
    center = (i * 7919) % (nx * ny * max(nz - 2, 1)) + nx * ny
    offs = np.array([0, -1, +1, -nx, +nx, -nx * ny, +nx * ny])
    reads = (center[:, None] + offs[None, :]) >> 3    # 8 points / line
    writes = (center >> 3) + footprint // 2           # output grid
    lines = np.concatenate(
        [reads, writes[:, None]], axis=1).reshape(-1)[:n]
    is_write = np.zeros(lines.shape[0], np.int32)
    is_write[7::8] = 1
    return _to_trace(lines, is_write, np.zeros(lines.shape[0]), footprint)


def spmv(n: int = 4096, footprint: int = DEFAULT_FOOTPRINT,
         seed: int = 0, nnz_per_row: int = 6) -> Trace:
    """CSR SpMV: per row, stream col-index+value lines, gather x, write y."""
    r = _rng("spmv", seed)
    per_row = nnz_per_row + 2         # nnz gathers + 1 stream + 1 write
    rows = n // per_row + 1
    lines, is_write = [], []
    vec_base = footprint // 2
    for row in range(rows):
        lines.append(row)                              # col_idx/val stream
        lines.extend(vec_base
                     + r.integers(0, footprint // 4, size=nnz_per_row))
        lines.append(3 * footprint // 4 + row)         # y[row] write
        is_write.extend([0] * (nnz_per_row + 1) + [1])
    lines = np.asarray(lines[:n])
    return _to_trace(lines, np.asarray(is_write[:n]),
                     np.zeros(lines.shape[0]), footprint)


def pointer_chase(n: int = 2048, footprint: int = DEFAULT_FOOTPRINT,
                  seed: int = 0) -> Trace:
    """Linked-list traversal: every load depends on the previous one."""
    r = _rng("pointer_chase", seed)
    lines = r.integers(0, footprint, size=n)
    dep = np.ones(n, np.int32)
    dep[0] = 0
    return _to_trace(lines, np.zeros(n), dep, footprint)


def bfs_frontier(n: int = 4096, footprint: int = DEFAULT_FOOTPRINT,
                 seed: int = 0, degree: int = 4) -> Trace:
    """BFS frontier expansion: stream a vertex, then dependent gathers."""
    r = _rng("bfs", seed)
    verts = n // (degree + 1) + 1
    lines, dep = [], []
    for v in range(verts):
        lines.append(v)                                # frontier stream
        dep.append(0)
        lines.extend(footprint // 2
                     + r.integers(0, footprint // 2, size=degree))
        dep.extend([1] + [0] * (degree - 1))           # burst waits on v
    lines = np.asarray(lines[:n])
    return _to_trace(lines, np.zeros(lines.shape[0]),
                     np.asarray(dep[:n]), footprint)


def mess_traffic(n: int = 4096, footprint: int = DEFAULT_FOOTPRINT,
                 seed: int = 0, write_num: int = 0) -> Trace:
    """The Mess generator loop as a trace: 64-line segments, hashed bases.

    Matches `workload.generate`'s traffic pattern (segmented sequential
    runs at scattered bases, deterministic write interleave at
    ``write_num/64``) so the trace frontend can be validated against the
    native pace frontend on statistically identical traffic.
    """
    r = _rng("mess", seed)
    segs = n // SEGMENT_LINES + 1
    bases = r.integers(0, footprint // SEGMENT_LINES,
                       size=segs) * SEGMENT_LINES
    k = np.arange(segs * SEGMENT_LINES)[:n]
    lines = bases[k // SEGMENT_LINES] + k % SEGMENT_LINES
    is_write = ((k + 1) * write_num) // 64 - (k * write_num) // 64 > 0
    return _to_trace(lines, is_write, np.zeros(n), footprint)


#: the application suite replayed by `benchmarks/app_validation.py`
KERNELS = {
    "stream": stream,
    "gups": gups,
    "stencil3d": stencil3d,
    "spmv": spmv,
    "pointer_chase": pointer_chase,
    "bfs_frontier": bfs_frontier,
}


def make_suite(n: int = 4096, footprint: int = DEFAULT_FOOTPRINT,
               seed: int = 0, names=None):
    """Generate the named kernels (all by default) as a list of traces."""
    names = tuple(names or KERNELS)
    unknown = [k for k in names if k not in KERNELS]
    if unknown:
        raise ValueError(
            f"unknown kernel(s) {unknown}; one of {sorted(KERNELS)}")
    return names, [KERNELS[k](n, footprint, seed) for k in names]
