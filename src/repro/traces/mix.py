"""Per-core trace assignment: multiprogrammed (mixed) workloads.

The replay frontend originally sharded *one* trace data-parallel across
every traffic core through a shared cursor — a multi-threaded kernel,
never a workload mix.  A `TraceMix` generalizes that: it is a
``(n_cores,)``-indexed batch of traces, padded to one static shape, so
each core replays *its own* stream with *its own* cursor.  This is the
regime where CPU-memory interface contention actually diverges across
the paper's three perspectives: a latency-bound app sharing the memory
system with a streaming app sees queueing delay the decoupled bound
phase never prices.

Construction is host-side numpy (`assign_traces`); the result is a
fixed-shape pytree, so a stack of mixes (`stack_mixes`) replays under
one `jax.vmap`-ed compile with the mix axis sharded across devices —
the same pattern solo suites use.

Per-core fields:

* ``length``    — valid prefix of the core's stream; 0 marks an *idle*
  core (it issues nothing — how partial-occupancy mixes and the chase
  core are encoded).
* ``pos0`` / ``line_cum0`` — the core's *phase offset*: the cursor
  starts ``pos0`` accesses into the stream (producer/consumer stagger
  within one app), with the delta prefix-sum at that point precomputed
  so absolute lines are identical to a core that replayed from 0.
* ``app_id``    — which application the core runs (-1 = idle); the
  replay engine reduces per-core completion windows to per-app
  runtimes with it.
* ``region_lines`` — static per-core address-region stride: core ``c``
  replays inside ``[c * region_lines, (c+1) * region_lines)``, keeping
  distinct apps in distinct physical regions.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.workload import CAP_DEMAND
from repro.traces.trace import MAX_FOOTPRINT_LINES, Trace


class TraceMix(NamedTuple):
    """A per-core trace batch (or a stack of them, with a leading axis)."""

    delta: jnp.ndarray            # (n_cores, L) int32
    is_write: jnp.ndarray         # (n_cores, L) int32 0/1
    dep: jnp.ndarray              # (n_cores, L) int32 0/1
    length: jnp.ndarray           # (n_cores,) int32; 0 = idle core
    footprint_lines: jnp.ndarray  # (n_cores,) int32 per-core mod wrap
    pos0: jnp.ndarray             # (n_cores,) int32 phase offset
    line_cum0: jnp.ndarray        # (n_cores,) int32 delta sum at pos0
    app_id: jnp.ndarray           # (n_cores,) int32; -1 = idle
    region_lines: jnp.ndarray     # ()  int32 per-core address stride

    @property
    def n_cores(self) -> int:
        return self.delta.shape[-2]

    @property
    def n_slots(self) -> int:
        return self.delta.shape[-1]


def assign_traces(traces: Sequence[Trace], assignment: Sequence[int],
                  phase_offsets: Sequence[int] | None = None,
                  wrap: bool = True) -> TraceMix:
    """Build a `TraceMix` from an app list and a per-core assignment.

    Args:
        traces: the applications of the mix (unbatched `Trace`s).
        assignment: per-core app index, one entry per frontend core
            (``len(assignment)`` must equal the platform's core count —
            24 per socket); -1 marks an idle core.  The chase-probe
            core (the last one) must be idle.
        phase_offsets: optional per-core start offsets into the
            assigned stream (accesses); cores of one app at different
            offsets model producer/consumer stagger.  Default: all
            zero.
        wrap: with ``True`` (default) an offset core replays the
            *rotated* stream ``[off, length) ++ [0, off)`` — the
            steady-state-pipeline model: every core replays the full
            ``length`` accesses regardless of its offset, and offsets
            are taken modulo the trace length.  The wrapped tail
            continues the running delta sum past the end of the stream,
            exactly as a looping replay would.  With ``False`` the
            offset core plays the truncated suffix ``[off, length)``
            (offsets clipped to the length) — the one-shot model, where
            an offset core finishes earlier.
    Returns:
        A `TraceMix` padded to one static shape: per-core arrays of
        length ``max(trace length) + CAP_DEMAND`` (the windowed
        `dynamic_slice` guard band, as in `make_trace`).
    """
    assignment = list(assignment)
    n_cores = len(assignment)
    if phase_offsets is None:
        phase_offsets = [0] * n_cores
    if len(phase_offsets) != n_cores:
        raise ValueError("phase_offsets must have one entry per core")
    if assignment[-1] != -1:
        raise ValueError("the last core is the chase probe; it must be "
                         "idle (app_id -1)")
    for a in assignment:
        if not -1 <= a < len(traces):
            raise ValueError(f"assignment entry {a} out of range for "
                             f"{len(traces)} traces")
    used = {a for a in assignment if a >= 0}
    missing = set(range(len(traces))) - used
    if missing:
        raise ValueError(f"traces {sorted(missing)} have no cores assigned")

    L = max(int(t.length) for t in traces) + CAP_DEMAND
    delta = np.zeros((n_cores, L), np.int32)
    is_write = np.zeros((n_cores, L), np.int32)
    dep = np.zeros((n_cores, L), np.int32)
    length = np.zeros(n_cores, np.int32)
    footprint = np.ones(n_cores, np.int32)
    pos0 = np.zeros(n_cores, np.int32)
    cum0 = np.zeros(n_cores, np.int32)

    host = [jax.tree_util.tree_map(np.asarray, t) for t in traces]
    for c, a in enumerate(assignment):
        if a < 0:
            continue
        t = host[a]
        n = int(t.length)
        delta[c, :t.delta.shape[0]] = t.delta
        is_write[c, :t.is_write.shape[0]] = t.is_write
        dep[c, :t.dep.shape[0]] = t.dep
        length[c] = n
        footprint[c] = int(t.footprint_lines)
        if wrap:
            # steady-state pipeline: rotate the stream so the cursor
            # starts at 0 and the core replays all n accesses
            off = int(phase_offsets[c]) % n if n else 0
            if off:
                for dst, src in ((delta, t.delta), (is_write, t.is_write),
                                 (dep, t.dep)):
                    dst[c, :n] = np.concatenate([src[off:n], src[:off]])
            pos0[c] = 0
        else:
            off = min(max(int(phase_offsets[c]), 0), n)
            pos0[c] = off
        # int32 wraparound on purpose: matches the frontend's running
        # line_cum, so an offset core addresses the same lines a
        # from-zero core would at the same position
        cum0[c] = np.asarray(t.delta[:off], np.int32).sum(dtype=np.int32)

    region = int(max(footprint.max(), 1))
    if region > MAX_FOOTPRINT_LINES:
        raise ValueError(
            f"footprint {region} exceeds {MAX_FOOTPRINT_LINES}")
    # per-core regions must stay below the chase-probe region (bit 31):
    # with two sockets (48 cores) large footprints can reach it
    if n_cores * region > 1 << 31:
        raise ValueError(
            f"{n_cores} cores x footprint {region} lines overflows the "
            f"2^31-line traffic address space (the chase-probe region "
            f"starts at bit 31); shrink the footprint")
    return TraceMix(
        delta=jnp.asarray(delta), is_write=jnp.asarray(is_write),
        dep=jnp.asarray(dep), length=jnp.asarray(length),
        footprint_lines=jnp.asarray(footprint),
        pos0=jnp.asarray(pos0), line_cum0=jnp.asarray(cum0),
        app_id=jnp.asarray(np.asarray(assignment, np.int32)),
        region_lines=jnp.asarray(region, jnp.int32),
    )


def split_cores(n_apps: int, n_cores: int) -> list[int]:
    """An even per-core assignment of ``n_apps`` over the traffic cores.

    Traffic cores (all but the last, which is the chase probe) are
    split into ``n_apps`` contiguous, near-equal blocks — app 0 on the
    first block and so on; the chase core is idle.
    """
    if n_apps < 1 or n_apps > n_cores - 1:
        raise ValueError(f"need 1..{n_cores - 1} apps, got {n_apps}")
    traffic = n_cores - 1
    out = []
    for c in range(traffic):
        out.append(min(c * n_apps // traffic, n_apps - 1))
    return out + [-1]


def stack_mixes(mixes: Sequence[TraceMix]) -> TraceMix:
    """Stack mixes to a batch, right-padding streams to a common L.

    All mixes must share one core count; the result replays under a
    single ``jax.vmap``-ed compile with the mix axis sharded across
    devices (`repro.core.shard.sharded_vmap`).
    """
    if len({m.n_cores for m in mixes}) != 1:
        raise ValueError("all mixes must have the same core count")
    L = max(m.n_slots for m in mixes)

    def padded(m: TraceMix):
        pad = L - m.n_slots
        return m._replace(
            delta=jnp.pad(m.delta, ((0, 0), (0, pad))),
            is_write=jnp.pad(m.is_write, ((0, 0), (0, pad))),
            dep=jnp.pad(m.dep, ((0, 0), (0, pad))),
        )

    return jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *[padded(m) for m in mixes])


def mix_stats(mix: TraceMix) -> dict:
    """Host-side summary of one (unbatched) mix."""
    app_id = np.asarray(mix.app_id)
    length = np.asarray(mix.length)
    apps = sorted(int(a) for a in set(app_id[app_id >= 0]))
    return dict(
        n_cores=mix.n_cores,
        n_apps=len(apps),
        cores_per_app={a: int((app_id == a).sum()) for a in apps},
        accesses_per_core={a: int(length[app_id == a].max(initial=0))
                           for a in apps},
        idle_cores=int((app_id < 0).sum()),
    )
