"""Trace-driven application workloads — the paper's third perspective.

Public API:

* `Trace`, `make_trace`, `stack_traces` — compact JAX-native traces.
* `KERNELS`, `make_suite`               — DAMOV-style app generators.
* `TraceFrontend`                       — bound-phase replay frontend.
* `replay_suite`, `replay_stages`       — device-sharded replay engine.
* `replay_grid`                         — preset x stage x app grid.
* `anchor_runtime_ms`, `mape`           — per-preset runtime anchors.
"""
from repro.traces.anchors import anchor_runtime_ms, anchor_suite_ms, mape
from repro.traces.frontend import TraceFrontend, TraceState
from repro.traces.kernels import KERNELS, make_suite
from repro.traces.replay import replay_grid, replay_stages, replay_suite
from repro.traces.trace import Trace, make_trace, stack_traces, trace_stats

__all__ = [
    "Trace", "make_trace", "stack_traces", "trace_stats",
    "KERNELS", "make_suite",
    "TraceFrontend", "TraceState",
    "replay_suite", "replay_stages", "replay_grid",
    "anchor_runtime_ms", "anchor_suite_ms", "mape",
]
