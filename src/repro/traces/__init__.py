"""Trace-driven application workloads — the paper's third perspective.

Public API:

* `Trace`, `make_trace`, `stack_traces` — compact JAX-native traces.
* `TraceMix`, `assign_traces`, `stack_mixes`, `split_cores` —
  per-core multiprogrammed trace assignment (`repro.traces.mix`).
* `KERNELS`, `make_suite`               — DAMOV-style app generators.
* `TraceFrontend`                       — per-core bound-phase replay
                                          frontend (solo trace or mix).
* `replay_suite`, `replay_stages`       — device-sharded replay engine.
* `replay_mix`, `replay_mixes`          — multiprogrammed replay with
                                          per-app-in-mix runtimes.
* `replay_grid`                         — preset x stage x app grid.
* `anchor_runtime_ms`, `anchor_mix_ms`, `mape` — per-preset runtime
                                          anchors (solo and mixed).
* `decode_cost`, `lower_decode`, `ServeScenario`, `simulate_schedule`,
  `lower_serving`, `lower_scenario`, `request_latencies_ms` —
  LLM-serving traffic lowered from the HLO cost model
  (`repro.traces.llm`, docs/SERVING.md).
"""
from repro.traces.anchors import (anchor_mix_ms, anchor_runtime_ms,
                                  anchor_suite_ms, mape)
from repro.traces.frontend import TraceFrontend, TraceState
from repro.traces.kernels import KERNELS, make_suite
from repro.traces.llm import (ServeScenario, decode_cost, decode_hlo,
                              lower_decode, lower_scenario, lower_serving,
                              request_latencies_ms, serving_terms,
                              simulate_schedule)
from repro.traces.mix import (TraceMix, assign_traces, mix_stats,
                              split_cores, stack_mixes)
from repro.traces.replay import (replay_grid, replay_mix, replay_mixes,
                                 replay_stages, replay_suite)
from repro.traces.trace import Trace, make_trace, stack_traces, trace_stats

__all__ = [
    "Trace", "make_trace", "stack_traces", "trace_stats",
    "TraceMix", "assign_traces", "stack_mixes", "split_cores", "mix_stats",
    "KERNELS", "make_suite",
    "TraceFrontend", "TraceState",
    "replay_suite", "replay_stages", "replay_grid",
    "replay_mix", "replay_mixes",
    "anchor_runtime_ms", "anchor_suite_ms", "anchor_mix_ms", "mape",
    "decode_hlo", "decode_cost", "lower_decode", "serving_terms",
    "ServeScenario", "simulate_schedule", "lower_serving",
    "lower_scenario", "request_latencies_ms",
]
