"""Mamba2 (SSD) blocks and the Zamba2 hybrid (zamba2-2.7b)
[arXiv:2405.21060, arXiv:2411.15242].

Mamba2 head-structured state space:
    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t      (A scalar per head)
    y_t = C_t . h_t + D x_t
Training uses the SSD *chunked* algorithm: within-chunk quadratic
(decay-masked) term + across-chunk recurrence carried by `lax.scan`,
so peak memory is (B, H, Q, Q) per chunk instead of (B, H, S, S).
Decode is the O(1) recurrent update (state (H, P, N) per layer) — the
property that makes the 500k-token decode cell run.

Zamba2 layout: ``n_layers`` Mamba2 blocks with ONE shared
attention+MLP transformer block applied every ``attn_every`` layers.
Following Zamba, the shared block reads concat(hidden, embedding) and
is projected back to d_model; each *application* keeps its own KV
cache (params shared, activations not).  We document (DESIGN.md) the
width simplification: the concat is linearly folded to d_model before
the shared block rather than running the block at 2x width.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.common import ModelConfig
from repro.parallel.axes import shard


# ---------------------------------------------------------------------------
# Mamba2 block


def init_mamba(cfg: ModelConfig, rng, scale: float):
    d = cfg.d_model
    d_in = cfg.d_inner
    n, h = cfg.ssm_state, cfg.ssm_heads
    conv_dim = d_in + 2 * n          # x, B, C share the conv
    ks = jax.random.split(rng, 5)
    return dict(
        norm=jnp.ones((d,), jnp.float32),
        w_in=jax.random.normal(
            ks[0], (d, 2 * d_in + 2 * n + h), jnp.float32) * scale,
        conv_w=jax.random.normal(
            ks[1], (cfg.conv_kernel, conv_dim), jnp.float32) * 0.1,
        conv_b=jnp.zeros((conv_dim,), jnp.float32),
        a_log=jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        dt_bias=jnp.zeros((h,), jnp.float32),
        d_skip=jnp.ones((h,), jnp.float32),
        norm_y=jnp.ones((d_in,), jnp.float32),
        w_out=jax.random.normal(ks[2], (d_in, d), jnp.float32) * scale,
    )


def mamba_specs(cfg: ModelConfig):
    return dict(norm=(None,), w_in=("fsdp", "state"),
                conv_w=(None, "state"), conv_b=("state",),
                a_log=(None,), dt_bias=(None,), d_skip=(None,),
                norm_y=("state",), w_out=("state", "fsdp"))


def _split_proj(cfg: ModelConfig, zxbcdt):
    d_in, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z, xs, bc, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + 2 * n], axis=-1)
    return z, xs, bc, dt


def _ssd_scan(cfg: ModelConfig, xh, dt, a, bmat, cmat):
    """SSD chunked scan.

    xh   (B,S,H,P)  inputs per head
    dt   (B,S,H)    positive step sizes
    a    (H,)       negative decay rates
    bmat (B,S,N), cmat (B,S,N)  shared across heads (n_groups=1)
    Returns y (B,S,H,P) fp32.
    """
    b, s, h, p = xh.shape
    n = bmat.shape[-1]
    q = min(cfg.ssm_chunk, s)
    s_pad = -(-s // q) * q
    if s_pad != s:
        # dt=0 padding is inert: decay exp(0)=1, zero input contribution
        pad = lambda t: jnp.pad(t, ((0, 0), (0, s_pad - s))
                                + ((0, 0),) * (t.ndim - 2))
        xh, dt, bmat, cmat = pad(xh), pad(dt), pad(bmat), pad(cmat)
    s_orig, s = s, s_pad
    nc = s // q
    da = dt * a[None, None, :]                        # (B,S,H), negative
    xb = (xh * dt[..., None]).astype(jnp.float32)     # dt-weighted input

    resh = lambda t: t.reshape(b, nc, q, *t.shape[2:])
    da_c, xb_c = resh(da), resh(xb)
    b_c, c_c = resh(bmat.astype(jnp.float32)), resh(cmat.astype(jnp.float32))
    cum = jnp.cumsum(da_c, axis=2)                    # (B,nc,q,H)

    # within-chunk (diagonal) term: decay-masked quadratic
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,q,q,H)
    iq = jnp.arange(q)
    mask = iq[:, None] >= iq[None, :]
    l_mat = jnp.where(mask[None, None, :, :, None], jnp.exp(rel), 0.0)
    cb = jnp.einsum("bkin,bkjn->bkij", c_c, b_c)          # (B,nc,q,q)
    y_diag = jnp.einsum("bkij,bkijh,bkjhp->bkihp",
                        cb, l_mat, xb_c)

    # chunk boundary states + across-chunk recurrence
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)       # (B,nc,q,H)
    states = jnp.einsum("bkjn,bkjh,bkjhp->bkhnp",
                        b_c, decay_to_end, xb_c)          # (B,nc,H,N,P)
    chunk_decay = jnp.exp(cum[:, :, -1, :])               # (B,nc,H)

    def scanb(h_prev, args):
        st, dec = args                                    # (B,H,N,P),(B,H)
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev

    _, h_prevs = jax.lax.scan(
        scanb, jnp.zeros((b, h, n, p), jnp.float32),
        (states.transpose(1, 0, 2, 3, 4),
         chunk_decay.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)            # (B,nc,H,N,P)

    # off-chunk term: contribution of the carried state
    decay_from_start = jnp.exp(cum)                       # (B,nc,q,H)
    y_off = jnp.einsum("bkin,bkih,bkhnp->bkihp",
                       c_c, decay_from_start, h_prevs)
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y[:, :s_orig]


def mamba_fwd(cfg: ModelConfig, p, x):
    dt_ = cfg.dtype
    d_in, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = cm.rmsnorm(x, p["norm"], cfg.norm_eps)
    zxbcdt = z @ p["w_in"].astype(dt_)
    zg, xs, bc, dtp = _split_proj(cfg, zxbcdt)

    # causal conv over (x, B, C)
    xbc = jnp.concatenate([xs, bc], axis=-1)
    k = cfg.conv_kernel
    xbc_pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    conv = sum(xbc_pad[:, i:i + xbc.shape[1]] * p["conv_w"][i].astype(dt_)
               for i in range(k)) + p["conv_b"].astype(dt_)
    conv = jax.nn.silu(conv)
    xs, bmat, cmat = jnp.split(conv, [d_in, d_in + n], axis=-1)

    dt = jax.nn.softplus(dtp.astype(jnp.float32)
                         + p["dt_bias"])                  # (B,S,H)
    a = -jnp.exp(p["a_log"])                              # (H,)
    xh = xs.reshape(*xs.shape[:2], h, cfg.ssm_head_dim)
    xh = shard(xh, "batch", None, "state", None)
    y = _ssd_scan(cfg, xh, dt, a, bmat, cmat)
    y = y + p["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(*y.shape[:2], d_in).astype(dt_)
    y = cm.rmsnorm(y * jax.nn.silu(zg), p["norm_y"], cfg.norm_eps)
    return x + y @ p["w_out"].astype(dt_)


def init_mamba_state(cfg: ModelConfig, batch: int):
    return dict(
        h=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state,
                     cfg.ssm_head_dim), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv_kernel - 1,
                        cfg.d_inner + 2 * cfg.ssm_state), jnp.float32),
    )


def mamba_step(cfg: ModelConfig, p, state, x):
    """One-token recurrent update.  x (B, d)."""
    dt_ = cfg.dtype
    d_in, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = cm.rmsnorm(x, p["norm"], cfg.norm_eps)
    zxbcdt = z @ p["w_in"].astype(dt_)
    zg, xs, bc, dtp = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([xs, bc], axis=-1)              # (B, conv_dim)
    hist = jnp.concatenate(
        [state["conv"], xbc[:, None, :].astype(jnp.float32)], axis=1)
    conv = (jnp.einsum("bkc,kc->bc", hist, p["conv_w"])
            + p["conv_b"])
    conv = jax.nn.silu(conv)
    xs, bmat, cmat = jnp.split(conv, [d_in, d_in + n], axis=-1)
    dt = jax.nn.softplus(dtp.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["a_log"])
    xh = xs.reshape(-1, h, cfg.ssm_head_dim)
    dec = jnp.exp(dt * a[None, :])                        # (B,H)
    hs = (state["h"] * dec[..., None, None]
          + jnp.einsum("bn,bhp->bhnp", bmat, xh * dt[..., None]))
    y = jnp.einsum("bn,bhnp->bhp", cmat, hs)
    y = y + p["d_skip"][None, :, None] * xh
    y = y.reshape(-1, d_in).astype(dt_)
    y = cm.rmsnorm(y * jax.nn.silu(zg), p["norm_y"], cfg.norm_eps)
    new_state = dict(h=hs, conv=hist[:, 1:])
    return new_state, x + y @ p["w_out"].astype(dt_)


# ---------------------------------------------------------------------------
# Zamba2 hybrid: mamba backbone + shared attention block


def _n_shared(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.attn_every


def init_shared_block(cfg: ModelConfig, rng, scale: float):
    k0, k1 = jax.random.split(rng)
    from repro.models.transformer import init_block
    return dict(
        w_cat=jax.random.normal(
            k0, (2 * cfg.d_model, cfg.d_model), jnp.float32) * scale,
        block=init_block(cfg, k1),
    )


def shared_block_specs(cfg: ModelConfig):
    from repro.models.transformer import block_specs
    return dict(w_cat=("fsdp", None), block=block_specs(cfg))


def init_params(cfg: ModelConfig, rng):
    from repro.models.transformer import stack_layers
    k_emb, k_m, k_s = jax.random.split(rng, 3)
    scale = 0.02 / (2 * cfg.n_layers) ** 0.5
    p = dict(
        embed=cm.init_embedding(cfg, k_emb),
        mamba=stack_layers(lambda r: init_mamba(cfg, r, scale), k_m,
                           cfg.n_layers),
    )
    if cfg.family == "hybrid":
        p["shared"] = init_shared_block(cfg, k_s, scale)
    return p


def param_specs(cfg: ModelConfig):
    from repro.models.transformer import stacked_specs
    p = dict(embed=cm.embedding_specs(cfg),
             mamba=stacked_specs(mamba_specs(cfg)))
    if cfg.family == "hybrid":
        p["shared"] = shared_block_specs(cfg)
    return p


def _shared_apply(cfg: ModelConfig, p, x, x0, positions):
    from repro.models.transformer import block_fwd
    u = jnp.concatenate([x, x0], axis=-1) @ p["w_cat"].astype(cfg.dtype)
    return x + block_fwd(cfg, p["block"], u, positions) - u  # residual on x


def forward(cfg: ModelConfig, params, tokens):
    x = cm.embed(cfg, params["embed"], tokens)
    x0 = x
    positions = jnp.arange(tokens.shape[1])[None, :]
    per = cfg.attn_every if cfg.family == "hybrid" else cfg.n_layers
    n_seg = cfg.n_layers // per
    mp = jax.tree_util.tree_map(
        lambda a: a.reshape(n_seg, per, *a.shape[1:]),
        cm.cast_params(cfg, params["mamba"]))

    @jax.checkpoint
    def mbody(x, lp):
        return mamba_fwd(cfg, lp, x), None

    for seg in range(n_seg):
        x, _ = jax.lax.scan(
            mbody, x, jax.tree_util.tree_map(lambda a: a[seg], mp))
        if cfg.family == "hybrid":
            x = _shared_apply(cfg, params["shared"], x, x0, positions)
    return cm.logits(cfg, params["embed"], x)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    rep = lambda st, nl: jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (nl,) + a.shape), st)
    cache = dict(mamba=rep(init_mamba_state(cfg, batch), cfg.n_layers),
                 length=jnp.zeros((batch,), jnp.int32))
    if cfg.family == "hybrid":
        n_sh = _n_shared(cfg)
        shape = (n_sh, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
        cache["shared_kv"] = dict(k=jnp.zeros(shape, cfg.dtype),
                                  v=jnp.zeros(shape, cfg.dtype))
    return cache


def cache_specs(cfg: ModelConfig, *, shard_seq: bool = True):
    spec = dict(
        mamba=dict(h=(None, "batch", "state", None, None),
                   conv=(None, "batch", None, "state")),
        length=(None,))
    if cfg.family == "hybrid":
        kv = (None, "batch", "kv_seq" if shard_seq else None,
              "kv_heads", None)
        spec["shared_kv"] = dict(k=kv, v=kv)
    return spec


def decode_step(cfg: ModelConfig, params, cache, tokens):
    from repro.models.transformer import decode_block
    x = cm.embed(cfg, params["embed"], tokens[:, None])[:, 0]
    x0 = x
    lengths = cache["length"]
    per = cfg.attn_every if cfg.family == "hybrid" else cfg.n_layers
    n_seg = cfg.n_layers // per
    mp = jax.tree_util.tree_map(
        lambda a: a.reshape(n_seg, per, *a.shape[1:]), params["mamba"])
    ms = jax.tree_util.tree_map(
        lambda a: a.reshape(n_seg, per, *a.shape[1:]), cache["mamba"])

    def mbody(x, scan_in):
        lp, st = scan_in
        st, x = mamba_step(cfg, lp, st, x)
        return x, st

    new_m, new_kv = [], []
    for seg in range(n_seg):
        x, st_out = jax.lax.scan(
            mbody, x, (jax.tree_util.tree_map(lambda a: a[seg], mp),
                       jax.tree_util.tree_map(lambda a: a[seg], ms)))
        new_m.append(st_out)
        if cfg.family == "hybrid":
            p_sh = params["shared"]
            u = (jnp.concatenate([x, x0], axis=-1)
                 @ p_sh["w_cat"].astype(cfg.dtype))[:, None, :]
            kv = jax.tree_util.tree_map(
                lambda a: a[seg], cache["shared_kv"])
            kv, u_out = decode_block(cfg, p_sh["block"], kv, u, lengths)
            new_kv.append(kv)
            x = x + u_out[:, 0] - u[:, 0]
    out = cm.logits(cfg, params["embed"], x[:, None])[:, 0]
    stackf = lambda lst: jax.tree_util.tree_map(lambda *a: jnp.stack(a), *lst)
    new_cache = dict(
        mamba=jax.tree_util.tree_map(
            lambda a: a.reshape(cfg.n_layers, *a.shape[2:]),
            stackf(new_m)),
        length=lengths + 1)
    if cfg.family == "hybrid":
        new_cache["shared_kv"] = stackf(new_kv)
    return out, new_cache
