"""Whisper-large-v3-style encoder-decoder backbone (audio).

Per the assignment the conv frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings (B, n_frames, d) — the encoder
consumes them directly.  Encoder: bidirectional self-attention blocks.
Decoder: causal self-attention + cross-attention over encoder output +
FFN, every layer.  MHA (n_kv_heads == n_heads == 20); on a 16-way
'model' axis the 20 heads replicate (divisibility fallback) while the
5120-wide FFN shards — see DESIGN.md §Arch-applicability.

Decode: self-attn KV cache + encoder K/V precomputed at prefill.
Encoder-decoder models have no single-stream "prefill"; the serve
path is encode() then decode_step().
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.common import ModelConfig
from repro.models import transformer as tf
from repro.parallel.axes import shard


def init_dec_block(cfg: ModelConfig, rng):
    k1, k2, k3 = jax.random.split(rng, 3)
    scale = 0.02 / (2 * cfg.n_layers) ** 0.5
    return dict(
        norm1=jnp.ones((cfg.d_model,), jnp.float32),
        attn=cm.init_attn(cfg, k1, scale),
        norm_x=jnp.ones((cfg.d_model,), jnp.float32),
        xattn=cm.init_attn(cfg, k2, scale),
        norm2=jnp.ones((cfg.d_model,), jnp.float32),
        mlp=cm.init_mlp(cfg, k3, scale, kind="gelu"),
    )


def dec_block_specs(cfg: ModelConfig):
    return dict(norm1=(None,), attn=cm.attn_specs(cfg), norm_x=(None,),
                xattn=cm.attn_specs(cfg), norm2=(None,),
                mlp=cm.mlp_specs("gelu"))


def init_params(cfg: ModelConfig, rng):
    k_emb, k_enc, k_dec = jax.random.split(rng, 3)
    scale = 0.02 / (2 * cfg.n_layers) ** 0.5
    return dict(
        embed=cm.init_embedding(cfg, k_emb),
        enc=tf.stack_layers(
            lambda r: tf.init_block(
                cfg, r, mlp_init=lambda rr: cm.init_mlp(
                    cfg, rr, scale, kind="gelu")),
            k_enc, cfg.n_encoder_layers),
        enc_norm=jnp.ones((cfg.d_model,), jnp.float32),
        dec=tf.stack_layers(lambda r: init_dec_block(cfg, r), k_dec,
                            cfg.n_layers),
    )


def param_specs(cfg: ModelConfig):
    return dict(
        embed=cm.embedding_specs(cfg),
        enc=tf.stacked_specs(tf.block_specs(cfg, cm.mlp_specs("gelu"))),
        enc_norm=(None,),
        dec=tf.stacked_specs(dec_block_specs(cfg)))


def encode(cfg: ModelConfig, params, frames):
    """frames (B, T_enc, d) stub embeddings -> encoder states."""
    x = shard(frames.astype(cfg.dtype), "batch", None, None)
    positions = jnp.arange(frames.shape[1])[None, :]

    @jax.checkpoint
    def body(x, lp):
        h = cm.rmsnorm(x, lp["norm1"], cfg.norm_eps)
        x = x + cm.self_attention(cfg, lp["attn"], h, positions,
                                  causal=False)
        h = cm.rmsnorm(x, lp["norm2"], cfg.norm_eps)
        x = x + cm.mlp(cfg, lp["mlp"], h, kind="gelu")
        return shard(x, "batch", None, None), None

    x, _ = jax.lax.scan(body, x, cm.cast_params(cfg, params["enc"]))
    return cm.rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def _dec_block_fwd(cfg: ModelConfig, lp, x, positions, enc):
    h = cm.rmsnorm(x, lp["norm1"], cfg.norm_eps)
    x = x + cm.self_attention(cfg, lp["attn"], h, positions)
    h = cm.rmsnorm(x, lp["norm_x"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, lp["xattn"]["wq"].astype(cfg.dtype))
    ek = jnp.einsum("btd,dhk->bthk", enc, lp["xattn"]["wk"].astype(cfg.dtype))
    ev = jnp.einsum("btd,dhk->bthk", enc, lp["xattn"]["wv"].astype(cfg.dtype))
    o = cm.attention(cfg, q, ek, ev, causal=False)
    x = x + cm.attn_out(cfg, lp["xattn"], o)
    h = cm.rmsnorm(x, lp["norm2"], cfg.norm_eps)
    x = x + cm.mlp(cfg, lp["mlp"], h, kind="gelu")
    return shard(x, "batch", None, None)


def forward(cfg: ModelConfig, params, tokens, frames):
    """Teacher-forced training: tokens (B,S) + frames (B,T_enc,d)."""
    enc = encode(cfg, params, frames)
    x = cm.embed(cfg, params["embed"], tokens)
    positions = jnp.arange(tokens.shape[1])[None, :]

    @jax.checkpoint
    def body(x, lp):
        return _dec_block_fwd(cfg, lp, x, positions, enc), None

    x, _ = jax.lax.scan(body, x, cm.cast_params(cfg, params["dec"]))
    return cm.logits(cfg, params["embed"], x)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    xshape = (cfg.n_layers, batch, cfg.n_ctx_tokens, cfg.n_kv_heads,
              cfg.head_dim)
    return dict(k=jnp.zeros(shape, cfg.dtype), v=jnp.zeros(shape, cfg.dtype),
                xk=jnp.zeros(xshape, cfg.dtype),
                xv=jnp.zeros(xshape, cfg.dtype),
                length=jnp.zeros((batch,), jnp.int32))


def cache_specs(cfg: ModelConfig, *, shard_seq: bool = True):
    kv = (None, "batch", "kv_seq" if shard_seq else None, "kv_heads", None)
    return dict(k=kv, v=kv, xk=kv, xv=kv, length=(None,))


def fill_cross_cache(cfg: ModelConfig, params, cache, frames):
    enc = encode(cfg, params, frames)

    def one(lp):
        ek = jnp.einsum("btd,dhk->bthk", enc,
                        lp["xattn"]["wk"].astype(cfg.dtype))
        ev = jnp.einsum("btd,dhk->bthk", enc,
                        lp["xattn"]["wv"].astype(cfg.dtype))
        return ek, ev

    ks, vs = jax.lax.map(one, params["dec"])
    return dict(cache, xk=ks.astype(cfg.dtype), xv=vs.astype(cfg.dtype))


def decode_step(cfg: ModelConfig, params, cache, tokens):
    x = cm.embed(cfg, params["embed"], tokens[:, None])
    lengths = cache["length"]

    def body2(x, scan_in):
        lp, kv, xk, xv = scan_in
        h = cm.rmsnorm(x, lp["norm1"], cfg.norm_eps)
        q, k_new, v_new = cm.attn_qkv(cfg, lp["attn"], h, lengths[:, None])
        upd = lambda c, n: jax.vmap(
            lambda cb, nb, lb: jax.lax.dynamic_update_slice_in_dim(
                cb, nb.astype(cb.dtype), lb, axis=0))(c, n, lengths)
        # pin cache layout (see transformer.decode_block)
        pin = lambda c: shard(c, "batch", "kv_seq", "kv_heads", None)
        kv = dict(k=pin(upd(kv["k"], k_new)), v=pin(upd(kv["v"], v_new)))
        o = tf.attention_over_cache(cfg, q, kv["k"], kv["v"], lengths + 1)
        x = x + cm.attn_out(cfg, lp["attn"], o)
        h = cm.rmsnorm(x, lp["norm_x"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h,
                       lp["xattn"]["wq"].astype(cfg.dtype))
        o = cm.attention(cfg, q, xk, xv, causal=False)
        x = x + cm.attn_out(cfg, lp["xattn"], o)
        h = cm.rmsnorm(x, lp["norm2"], cfg.norm_eps)
        x = x + cm.mlp(cfg, lp["mlp"], h, kind="gelu")
        return x, kv

    x, kv = jax.lax.scan(
        body2, x, (params["dec"], dict(k=cache["k"], v=cache["v"]),
                   cache["xk"], cache["xv"]))
    out = cm.logits(cfg, params["embed"], x)[:, 0]
    return out, dict(k=kv["k"], v=kv["v"], xk=cache["xk"], xv=cache["xv"],
                     length=lengths + 1)
