"""Shared model machinery: config, norms, RoPE, GQA attention, FFN.

Conventions
-----------
* Params are nested dicts of jnp arrays; per-layer groups are *stacked*
  along a leading ``L`` axis and consumed by ``jax.lax.scan`` (compact
  HLO — essential for 80-layer archs lowered on 512 host devices).
* Every model provides a parallel *spec tree*: same structure as the
  params, leaves = tuples of logical axis names (see `parallel.axes`).
* Compute dtype is ``cfg.dtype`` (bf16 by default); params and softmax
  accumulate in fp32.
* Attention has two interchangeable implementations: the pure-jnp
  query-chunked online-softmax path (used for lowering/training — XLA
  TPU fuses it well and it lowers on any backend) and the Pallas
  flash-attention kernel (``repro.kernels.flash_attention``; TPU
  execution path, validated in interpret mode).  ``cfg.use_flash_kernel``
  selects.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.parallel.axes import _mesh, resolve, serving_mode, shard


def serving_matmul(x, w, eq: str, w_logical: tuple):
    """Weight-stationary projection for serving (§Perf iteration 3).

    ``x @ w`` where w's contraction dim(s) may be sharded (serve rules
    put 'embed'/'mlp' on the data axis).  XLA's SPMD heuristic resolves
    that by ALL-GATHERING the weights every step — at decode that is
    the whole model per step.  This helper pins the weight-stationary
    schedule with shard_map: x is replicated in (decode activations
    are tiny), each device contracts against its resident weight
    shard, and partial products are psum'd over the contraction axes.
    Falls back to a plain einsum outside serving mode.
    """
    if not serving_mode() or _mesh() is None:
        return jnp.einsum(eq, x, w)
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = _mesh()
    w_spec = resolve(w_logical, w.shape)
    ins, out = eq.split("->")
    x_dims, w_dims = ins.split(",")
    flat = lambda a: (() if a is None
                      else (a,) if isinstance(a, str) else tuple(a))
    w_axes = {dim: (w_spec[i] if i < len(w_spec) else None)
              for i, dim in enumerate(w_dims)}
    # contraction = w dims absent from the output -> psum over their axes
    psum_axes = [ax for dim in w_dims if dim not in out
                 for ax in flat(w_axes[dim])]
    # x/out dims mirror w's sharding where labels are shared
    x_spec = P(*(w_axes.get(dim) for dim in x_dims))
    o_spec = P(*(w_axes.get(dim) for dim in out))

    def local(xl, wl):
        y = jnp.einsum(eq, xl, wl)
        return jax.lax.psum(y, tuple(psum_axes)) if psum_axes else y

    return shard_map(local, mesh=mesh, in_specs=(x_spec, P(*w_spec)),
                     out_specs=o_spec, check_rep=False)(x, w)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config type for every assigned architecture family."""

    name: str = "model"
    family: str = "dense"          # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab: int = 1024
    d_head: int = 0                # 0 -> d_model // n_heads
    qkv_bias: bool = False         # qwen2 uses QKV bias
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: Any = jnp.bfloat16
    use_flash_kernel: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 2
    dense_residual: bool = False   # arctic: dense FFN in parallel w/ MoE
    capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    ssm_state: int = 64            # Mamba2 state size N
    ssm_expand: int = 2            # d_inner = expand * d_model
    ssm_head_dim: int = 64         # Mamba2 head dim P
    ssm_chunk: int = 128           # SSD chunk length
    conv_kernel: int = 4
    attn_every: int = 6            # zamba: shared attn block period
    slstm_every: int = 8           # xlstm: sLSTM block period
    # --- cross-attention (vlm) / encoder-decoder (audio) ---
    cross_attn_every: int = 0      # vlm: cross-attn layer period
    n_encoder_layers: int = 0      # whisper encoder depth
    n_ctx_tokens: int = 1500       # stub frontend tokens (frames/patches)
    # --- attention flavor ---
    attn_logit_softcap: float = 0.0   # grok-1 uses 30.0
    max_seq: int = 8192            # rope table length for training

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim


# ---------------------------------------------------------------------------
# primitives


def cast_params(cfg: ModelConfig, tree):
    """Cast fp32 weights to the compute dtype BEFORE the layer scan.

    §Perf iteration 7: with the cast inside the layer body, the FSDP
    all-gather moves fp32 master weights and each device casts after —
    2x the collective bytes and 2x the HBM weight reads.  Hoisting the
    cast outside the scan ships bf16 (numerics identical: same cast,
    earlier).  fp32 master copies remain in the optimizer path.
    """
    return jax.tree_util.tree_map(
        lambda a: a.astype(cfg.dtype)
        if hasattr(a, "dtype") and a.dtype == jnp.float32 else a, tree)


def rmsnorm(x, w, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, w, b, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def rope_table(positions, head_dim: int, theta: float):
    """positions (...,) -> cos/sin tables (..., head_dim//2)."""
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (..., S, H, D); cos/sin (..., S, D//2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c, s = cos[..., None, :], sin[..., None, :]
    return jnp.concatenate(
        [x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


def heads_tp_available(n: int) -> bool:
    """True if `n` heads can shard the 'model' axis (divisibility).

    REPRO_NO_SP=1 disables the sequence-parallel fallback (§Perf A/B
    measurement knob).
    """
    import os
    if os.environ.get("REPRO_NO_SP"):
        return True
    spec = resolve(("heads",), (n,))
    return len(spec) > 0 and spec[0] is not None


def _probs_dtype():
    """bf16 unless REPRO_FP32_PROBS=1 (§Perf iteration-1 A/B knob)."""
    import os
    return jnp.float32 if os.environ.get("REPRO_FP32_PROBS") \
        else jnp.bfloat16


def _chunked_attention(q, k, v, *, causal: bool, chunk: int,
                       softcap: float = 0.0):
    """Query-chunked online attention, fp32 softmax, grouped GQA.

    q (B,S,Hq,D); k,v (B,T,Hkv,D), Hq % Hkv == 0.  The GQA group dim is
    contracted by einsum — the repeated-KV tensor is NEVER materialized
    (a `jnp.repeat` here costs Hq/Hkv x KV memory AND forces SPMD to
    reshard the expanded heads; see EXPERIMENTS.md §Perf).  Scans over
    query chunks so peak score memory is (B,Hkv,G,chunk,T).
    """
    b, s, hq, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = 1.0 / (d ** 0.5)
    chunk = min(chunk, max(-(-s // 128) * 128, 128))   # no padding waste
    nq = -(-s // chunk)
    s_pad = nq * chunk
    qp = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
    qc = (qp.reshape(b, nq, chunk, hkv, g, d)
          .transpose(1, 0, 2, 3, 4, 5))          # (nq,B,c,Hkv,G,D)
    # Sequence-parallel fallback (§Perf iteration 5): when the head
    # count cannot shard the 'model' axis (whisper: 20 heads on 16),
    # the score computation would be replicated 16x across it.  Shard
    # the query-chunk dim instead — each model shard owns a slice of
    # the rows, k/v are shared, and the heavy score tensors shrink by
    # the TP degree.
    seq_par = not heads_tp_available(hq)

    def body(_, args):
        i, qi = args
        if seq_par:
            qi = shard(qi, "batch", "seq", None, None, None)
        sc = jnp.einsum("bchgd,bthd->bchgt", qi.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
        if seq_par:
            sc = shard(sc, "batch", "seq", None, None, None)
        if softcap > 0.0:
            sc = softcap * jnp.tanh(sc / softcap)
        if causal:
            qpos = (i * chunk + jnp.arange(chunk)[:, None]
                    + (t - s))                    # (c,1)
            kpos = jnp.arange(t)[None, :]
            msk = (kpos <= qpos)[None, :, None, None, :]
            sc = jnp.where(msk, sc, -jnp.inf)
        m = jnp.max(sc, axis=-1, keepdims=True)
        p = jnp.exp(sc - jax.lax.stop_gradient(jnp.where(
            jnp.isfinite(m), m, 0.0)))
        l = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
        # §Perf iter 1: probabilities cross HBM in bf16 (the softmax
        # stats m/l stay fp32).  Score-sized tensors dominate the
        # memory roofline term; this halves their traffic.  The PV
        # matmul accumulates in fp32 (preferred_element_type).
        o = jnp.einsum("bchgt,bthd->bchgd", p.astype(_probs_dtype()),
                       v.astype(_probs_dtype()),
                       preferred_element_type=jnp.float32)
        o = o / l
        return None, o.astype(q.dtype)

    _, oc = jax.lax.scan(body, None, (jnp.arange(nq), qc))
    o = oc.transpose(1, 0, 2, 3, 4, 5).reshape(b, s_pad, hq, d)
    return o[:, :s]


def attention(cfg: ModelConfig, q, k, v, *, causal: bool, chunk: int = 1024):
    """GQA attention dispatch (jnp chunked path or Pallas kernel).

    q (B,S,Hq,D); k,v (B,T,Hkv,D).  Returns (B,S,Hq,D).
    """
    if cfg.use_flash_kernel:
        from repro.kernels.flash_attention import flash_attention
        o = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), causal=causal)
        return o.transpose(0, 2, 1, 3)
    return _chunked_attention(q, k, v, causal=causal, chunk=chunk,
                              softcap=cfg.attn_logit_softcap)


# ---------------------------------------------------------------------------
# attention + FFN layers (param dicts + spec trees)


def init_attn(cfg: ModelConfig, rng, scale: float):
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 4)
    p = dict(
        wq=jax.random.normal(ks[0], (d, hq, dh), jnp.float32) * scale,
        wk=jax.random.normal(ks[1], (d, hkv, dh), jnp.float32) * scale,
        wv=jax.random.normal(ks[2], (d, hkv, dh), jnp.float32) * scale,
        wo=jax.random.normal(ks[3], (hq, dh, d), jnp.float32) * scale,
    )
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq, dh), jnp.float32)
        p["bk"] = jnp.zeros((hkv, dh), jnp.float32)
        p["bv"] = jnp.zeros((hkv, dh), jnp.float32)
    return p


def attn_specs(cfg: ModelConfig):
    # 'embed' == 'fsdp' under training rules; under serving rules it
    # keeps the d_model dim data-sharded (resident weights) instead of
    # replicating when the head count does not divide the model axis.
    p = dict(wq=("embed", "heads", None), wk=("embed", "kv_heads", None),
             wv=("embed", "kv_heads", None), wo=("heads", None, "embed"))
    if cfg.qkv_bias:
        p.update(bq=("heads", None), bk=("kv_heads", None),
                 bv=("kv_heads", None))
    return p


def attn_qkv(cfg: ModelConfig, p, x, positions):
    """Project + rope.  x (B,S,d) -> q (B,S,Hq,D), k/v (B,S,Hkv,D)."""
    dt = cfg.dtype
    specs = attn_specs(cfg)
    q = serving_matmul(x, p["wq"].astype(dt), "bsd,dhk->bshk",
                       specs["wq"])
    k = serving_matmul(x, p["wk"].astype(dt), "bsd,dhk->bshk",
                       specs["wk"])
    v = serving_matmul(x, p["wv"].astype(dt), "bsd,dhk->bshk",
                       specs["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    cos, sin = rope_table(positions, cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    return q, k, v


def attn_out(cfg: ModelConfig, p, o):
    return serving_matmul(o, p["wo"].astype(cfg.dtype), "bshk,hkd->bsd",
                          attn_specs(cfg)["wo"])


def self_attention(cfg: ModelConfig, p, x, positions, *, causal=True):
    q, k, v = attn_qkv(cfg, p, x, positions)
    o = attention(cfg, q, k, v, causal=causal)
    return attn_out(cfg, p, o)


def init_mlp(cfg: ModelConfig, rng, scale: float, kind: str = "swiglu",
             d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    if kind == "swiglu":
        return dict(
            w_gate=jax.random.normal(ks[0], (d, f), jnp.float32) * scale,
            w_up=jax.random.normal(ks[1], (d, f), jnp.float32) * scale,
            w_down=jax.random.normal(ks[2], (f, d), jnp.float32) * scale,
        )
    return dict(   # gelu (whisper)
        w_up=jax.random.normal(ks[0], (d, f), jnp.float32) * scale,
        b_up=jnp.zeros((f,), jnp.float32),
        w_down=jax.random.normal(ks[1], (f, d), jnp.float32) * scale,
        b_down=jnp.zeros((d,), jnp.float32),
    )


def mlp_specs(kind: str = "swiglu"):
    if kind == "swiglu":
        return dict(w_gate=("embed", "mlp"), w_up=("embed", "mlp"),
                    w_down=("mlp", "embed"))
    return dict(w_up=("embed", "mlp"), b_up=("mlp",),
                w_down=("mlp", "embed"), b_down=(None,))


def mlp(cfg: ModelConfig, p, x, kind: str = "swiglu"):
    dt = cfg.dtype
    specs = mlp_specs(kind)
    mm = lambda a, name: serving_matmul(a, p[name].astype(dt),
                                        "bsd,df->bsf", specs[name])
    if kind == "swiglu":
        h = jax.nn.silu(mm(x, "w_gate")) * mm(x, "w_up")
        h = shard(h, "batch", None, "mlp")
        return serving_matmul(h, p["w_down"].astype(dt), "bsf,fd->bsd",
                              specs["w_down"])
    h = jax.nn.gelu(mm(x, "w_up") + p["b_up"].astype(dt))
    h = shard(h, "batch", None, "mlp")
    return serving_matmul(h, p["w_down"].astype(dt), "bsf,fd->bsd",
                          specs["w_down"]) + p["b_down"].astype(dt)


def init_embedding(cfg: ModelConfig, rng):
    ks = jax.random.split(rng, 2)
    p = dict(
        tok=jax.random.normal(ks[0], (cfg.vocab, cfg.d_model),
                              jnp.float32) * 0.02,
        norm_f=jnp.ones((cfg.d_model,), jnp.float32),
    )
    if not cfg.tie_embeddings:
        p["head"] = jax.random.normal(
            ks[1], (cfg.d_model, cfg.vocab), jnp.float32) * 0.02
    return p


def embedding_specs(cfg: ModelConfig):
    p = dict(tok=("vocab", "embed"), norm_f=(None,))
    if not cfg.tie_embeddings:
        p["head"] = ("embed", "vocab")
    return p


def embed(cfg: ModelConfig, p, tokens):
    x = jnp.take(p["tok"].astype(cfg.dtype), tokens, axis=0)
    return shard(x, "batch", None, None)


def logits(cfg: ModelConfig, p, x):
    x = rmsnorm(x, p["norm_f"], cfg.norm_eps)
    w = (p["tok"].T if cfg.tie_embeddings else p["head"]).astype(cfg.dtype)
    out = x @ w
    return shard(out, "batch", None, "vocab")
