"""Llama-3.2-Vision-style VLM backbone (llama-3.2-vision-11b).

40 decoder layers of which every ``cross_attn_every``-th is a *gated
cross-attention* layer over precomputed image patch embeddings (the
modality frontend is a stub per the assignment: ``input_specs()``
provides the patch embeddings).  Structure per segment:
(cross_attn_every - 1) self-attention blocks scanned, then one gated
cross block (Flamingo-style tanh gates, init 0 -> identity at init).

Serving: self layers keep a KV cache; cross layers precompute the
image K/V once at prefill and reuse them every decode step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.common import ModelConfig
from repro.models import transformer as tf
from repro.parallel.axes import shard


def _segments(cfg: ModelConfig):
    per = cfg.cross_attn_every
    assert per > 1 and cfg.n_layers % per == 0, (cfg.n_layers, per)
    return cfg.n_layers // per, per - 1


def init_cross_block(cfg: ModelConfig, rng, scale: float):
    k1, k2 = jax.random.split(rng)
    return dict(
        norm1=jnp.ones((cfg.d_model,), jnp.float32),
        attn=cm.init_attn(cfg, k1, scale),
        gate_attn=jnp.zeros((), jnp.float32),
        norm2=jnp.ones((cfg.d_model,), jnp.float32),
        mlp=cm.init_mlp(cfg, k2, scale),
        gate_mlp=jnp.zeros((), jnp.float32),
    )


def cross_block_specs(cfg: ModelConfig):
    return dict(norm1=(None,), attn=cm.attn_specs(cfg), gate_attn=(),
                norm2=(None,), mlp=cm.mlp_specs(), gate_mlp=())


def init_params(cfg: ModelConfig, rng):
    n_seg, n_self = _segments(cfg)
    k_emb, k_s, k_x = jax.random.split(rng, 3)
    scale = 0.02 / (2 * cfg.n_layers) ** 0.5
    return dict(
        embed=cm.init_embedding(cfg, k_emb),
        layers=tf.stack_layers(
            lambda r: tf.init_block(cfg, r), k_s, n_seg * n_self),
        cross=tf.stack_layers(
            lambda r: init_cross_block(cfg, r, scale), k_x, n_seg),
    )


def param_specs(cfg: ModelConfig):
    return dict(embed=cm.embedding_specs(cfg),
                layers=tf.stacked_specs(tf.block_specs(cfg)),
                cross=tf.stacked_specs(cross_block_specs(cfg)))


def _cross_kv(cfg: ModelConfig, p, ctx):
    dt = cfg.dtype
    k = jnp.einsum("btd,dhk->bthk", ctx, p["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", ctx, p["wv"].astype(dt))
    return k, v


def _cross_apply(cfg: ModelConfig, p, x, ck, cv):
    """Gated cross-attention block; ck/cv precomputed image K/V."""
    h = cm.rmsnorm(x, p["norm1"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wq"].astype(cfg.dtype))
    q = shard(q, "batch", None, "heads", None)
    o = cm.attention(cfg, q, ck, cv, causal=False)
    x = x + jnp.tanh(p["gate_attn"]) * cm.attn_out(cfg, p["attn"], o)
    h = cm.rmsnorm(x, p["norm2"], cfg.norm_eps)
    x = x + jnp.tanh(p["gate_mlp"]) * cm.mlp(cfg, p["mlp"], h)
    return x


def forward(cfg: ModelConfig, params, tokens, ctx):
    """tokens (B,S); ctx (B, n_ctx, d) precomputed patch embeddings."""
    n_seg, n_self = _segments(cfg)
    x = cm.embed(cfg, params["embed"], tokens)
    ctx = shard(ctx.astype(cfg.dtype), "batch", None, None)
    positions = jnp.arange(tokens.shape[1])[None, :]
    lp = jax.tree_util.tree_map(
        lambda a: a.reshape(n_seg, n_self, *a.shape[1:]),
        cm.cast_params(cfg, params["layers"]))

    @jax.checkpoint
    def body(x, layer_p):
        return tf.block_fwd(cfg, layer_p, x, positions), None

    for seg in range(n_seg):
        x, _ = jax.lax.scan(
            body, x, jax.tree_util.tree_map(lambda a: a[seg], lp))
        pc = jax.tree_util.tree_map(lambda a: a[seg], params["cross"])
        ck, cv = _cross_kv(cfg, pc["attn"], ctx)
        x = _cross_apply(cfg, pc, x, ck, cv)
    return cm.logits(cfg, params["embed"], x)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    n_seg, n_self = _segments(cfg)
    shape = (n_seg * n_self, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    xshape = (n_seg, batch, cfg.n_ctx_tokens, cfg.n_kv_heads, cfg.head_dim)
    return dict(k=jnp.zeros(shape, cfg.dtype), v=jnp.zeros(shape, cfg.dtype),
                xk=jnp.zeros(xshape, cfg.dtype),
                xv=jnp.zeros(xshape, cfg.dtype),
                length=jnp.zeros((batch,), jnp.int32))


def cache_specs(cfg: ModelConfig, *, shard_seq: bool = True):
    kv = (None, "batch", "kv_seq" if shard_seq else None, "kv_heads", None)
    return dict(k=kv, v=kv, xk=kv, xv=kv, length=(None,))


def fill_cross_cache(cfg: ModelConfig, params, cache, ctx):
    """Precompute per-segment image K/V (prefill side)."""
    ctx = ctx.astype(cfg.dtype)
    ks, vs = [], []
    for seg in range(params["cross"]["gate_attn"].shape[0]):
        pc = jax.tree_util.tree_map(lambda a: a[seg], params["cross"])
        k, v = _cross_kv(cfg, pc["attn"], ctx)
        ks.append(k)
        vs.append(v)
    return dict(cache, xk=jnp.stack(ks).astype(cfg.dtype),
                xv=jnp.stack(vs).astype(cfg.dtype))


def decode_step(cfg: ModelConfig, params, cache, tokens):
    n_seg, n_self = _segments(cfg)
    x = cm.embed(cfg, params["embed"], tokens[:, None])
    lengths = cache["length"]
    lp = jax.tree_util.tree_map(
        lambda a: a.reshape(n_seg, n_self, *a.shape[1:]), params["layers"])
    kv = jax.tree_util.tree_map(
        lambda a: a.reshape(n_seg, n_self, *a.shape[1:]),
        dict(k=cache["k"], v=cache["v"]))

    def body(x, scan_in):
        layer_p, kv1 = scan_in
        kv1, x = tf.decode_block(cfg, layer_p, kv1, x, lengths)
        return x, kv1

    outs = []
    for seg in range(n_seg):
        x, kv_out = jax.lax.scan(
            body, x, (jax.tree_util.tree_map(lambda a: a[seg], lp),
                      jax.tree_util.tree_map(lambda a: a[seg], kv)))
        outs.append(kv_out)
        pc = jax.tree_util.tree_map(lambda a: a[seg], params["cross"])
        x = _cross_apply(cfg, pc, x, cache["xk"][seg], cache["xv"][seg])
    stackf = lambda lst: jax.tree_util.tree_map(lambda *a: jnp.stack(a), *lst)
    kv_new = jax.tree_util.tree_map(
        lambda a: a.reshape(n_seg * n_self, *a.shape[2:]), stackf(outs))
    out = cm.logits(cfg, params["embed"], x)[:, 0]
    return out, dict(k=kv_new["k"], v=kv_new["v"], xk=cache["xk"],
                     xv=cache["xv"], length=lengths + 1)
