"""Family dispatch: one uniform model API over all six families.

    api = get_model(cfg)
    params = api.init(rng)
    logits = api.forward(params, batch)          # batch dict, see below
    cache  = api.init_cache(batch_size, max_seq)
    logits, cache = api.decode(params, cache, tokens)

Batch dict keys: ``tokens`` always; ``ctx`` for vlm (patch embeddings)
and audio (frame embeddings).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    init: Callable[..., Any]
    forward: Callable[..., Any]            # (params, batch) -> logits
    param_specs: Callable[[], Any]
    init_cache: Callable[..., Any]         # (batch, max_seq) -> cache
    cache_specs: Callable[..., Any]        # (shard_seq=...) -> spec tree
    decode: Callable[..., Any]             # (params, cache, tokens)
    fill_ctx: Callable[..., Any] | None = None   # (params, cache, ctx)
    needs_ctx: bool = False


def get_model(cfg: ModelConfig) -> ModelApi:
    fam = cfg.family
    if fam == "dense":
        from repro.models import transformer as m
        return ModelApi(
            cfg=cfg,
            init=lambda rng: m.init_params(cfg, rng),
            forward=lambda p, b: m.forward(cfg, p, b["tokens"]),
            param_specs=lambda: m.param_specs(cfg),
            init_cache=lambda bs, ms: m.init_cache(cfg, bs, ms),
            cache_specs=lambda **kw: m.cache_specs(cfg, **kw),
            decode=lambda p, c, t: m.decode_step(cfg, p, c, t))
    if fam == "moe":
        from repro.models import transformer as m
        from repro.models import moe
        import functools
        mlp_init = functools.partial(
            moe.init_moe, cfg,
            scale=0.02 / (2 * cfg.n_layers) ** 0.5)
        mlp_fn = functools.partial(moe.moe_mlp_y, cfg)
        return ModelApi(
            cfg=cfg,
            init=lambda rng: m.init_params(
                cfg, rng, mlp_init=lambda r: mlp_init(r)),
            forward=lambda p, b: m.forward(cfg, p, b["tokens"],
                                           mlp_fn=mlp_fn),
            param_specs=lambda: m.param_specs(cfg, moe.moe_specs(cfg)),
            init_cache=lambda bs, ms: m.init_cache(cfg, bs, ms),
            cache_specs=lambda **kw: m.cache_specs(cfg, **kw),
            decode=lambda p, c, t: m.decode_step(cfg, p, c, t,
                                                 mlp_fn=mlp_fn))
    if fam == "ssm":
        if cfg.d_ff == 0 and cfg.slstm_every:     # xlstm
            from repro.models import xlstm as m
        else:
            from repro.models import mamba2 as m
        return ModelApi(
            cfg=cfg,
            init=lambda rng: m.init_params(cfg, rng),
            forward=lambda p, b: m.forward(cfg, p, b["tokens"]),
            param_specs=lambda: m.param_specs(cfg),
            init_cache=lambda bs, ms: m.init_cache(cfg, bs, ms),
            cache_specs=lambda **kw: m.cache_specs(cfg, **kw),
            decode=lambda p, c, t: m.decode_step(cfg, p, c, t))
    if fam == "hybrid":
        from repro.models import mamba2 as m
        return ModelApi(
            cfg=cfg,
            init=lambda rng: m.init_params(cfg, rng),
            forward=lambda p, b: m.forward(cfg, p, b["tokens"]),
            param_specs=lambda: m.param_specs(cfg),
            init_cache=lambda bs, ms: m.init_cache(cfg, bs, ms),
            cache_specs=lambda **kw: m.cache_specs(cfg, **kw),
            decode=lambda p, c, t: m.decode_step(cfg, p, c, t))
    if fam == "vlm":
        from repro.models import vlm as m
        return ModelApi(
            cfg=cfg,
            init=lambda rng: m.init_params(cfg, rng),
            forward=lambda p, b: m.forward(cfg, p, b["tokens"], b["ctx"]),
            param_specs=lambda: m.param_specs(cfg),
            init_cache=lambda bs, ms: m.init_cache(cfg, bs, ms),
            cache_specs=lambda **kw: m.cache_specs(cfg, **kw),
            decode=lambda p, c, t: m.decode_step(cfg, p, c, t),
            fill_ctx=lambda p, c, ctx: m.fill_cross_cache(cfg, p, c, ctx),
            needs_ctx=True)
    if fam == "audio":
        from repro.models import whisper as m
        return ModelApi(
            cfg=cfg,
            init=lambda rng: m.init_params(cfg, rng),
            forward=lambda p, b: m.forward(cfg, p, b["tokens"], b["ctx"]),
            param_specs=lambda: m.param_specs(cfg),
            init_cache=lambda bs, ms: m.init_cache(cfg, bs, ms),
            cache_specs=lambda **kw: m.cache_specs(cfg, **kw),
            decode=lambda p, c, t: m.decode_step(cfg, p, c, t),
            fill_ctx=lambda p, c, ctx: m.fill_cross_cache(cfg, p, c, ctx),
            needs_ctx=True)
    raise ValueError(f"unknown family {fam!r}")


def count_params(params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))
