"""xLSTM (xlstm-1.3b): mLSTM + sLSTM blocks [arXiv:2405.04517].

Layout: ``slstm_every``-periodic — each segment is (slstm_every - 1)
mLSTM blocks followed by one sLSTM block (48 layers = 6 segments of
7 mLSTM + 1 sLSTM).  mLSTM segments run under `lax.scan` over stacked
params; sLSTM blocks are individual (their recurrence scans over time).

mLSTM (matrix-memory LSTM, exponential gating):
    C_t = f_t C_{t-1} + i_t v_t k_t^T ;  n_t = f_t n_{t-1} + i_t k_t
    h_t = C_t q_t / max(|n_t . q_t|, 1)
with log-domain stabilizer m_t.  Training uses the quadratic parallel
form, *query-chunked* like flash attention so peak score memory is
(B, H, chunk, S); decode is the O(1) recurrent update — this is what
makes the 500k-token decode cell tractable (state is (H, dh, dh), not
a KV cache).

sLSTM (scalar-memory, recurrent gating) is inherently sequential —
implemented as `lax.scan` over time.

TP: heads are few (4) and do not divide a 16-way 'model' axis; the
value/output dimension carries the tensor parallelism instead (logical
axis 'state' on dh), which shards C on its value row dim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.common import ModelConfig
from repro.parallel.axes import shard


def _dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    h = cfg.n_heads
    dh = d_in // h
    return d_in, h, dh


# ---------------------------------------------------------------------------
# mLSTM block


def init_mlstm(cfg: ModelConfig, rng, scale: float):
    """Official mLSTM block shape: one up-projection d -> 2*d_in, then
    per-head *block-diagonal* q/k/v over the up-projected halves (this
    is what makes the published 1.3B size work; dense d->d_in q/k/v
    would nearly double the block)."""
    d = cfg.d_model
    d_in, h, dh = _dims(cfg)
    ks = jax.random.split(rng, 6)
    bd = lambda k: jax.random.normal(k, (h, dh, dh), jnp.float32) * scale
    return dict(
        norm=jnp.ones((d,), jnp.float32),
        w_up=jax.random.normal(ks[0], (d, 2 * d_in), jnp.float32) * scale,
        wq=bd(ks[1]), wk=bd(ks[2]), wv=bd(ks[3]),
        wif=jax.random.normal(ks[4], (d, h, 2), jnp.float32) * 0.02,
        bif=jnp.concatenate([jnp.zeros((h, 1)), 3.0 * jnp.ones((h, 1))],
                            axis=1).astype(jnp.float32),
        wo=jax.random.normal(ks[5], (h, dh, d), jnp.float32) * scale,
    )


def mlstm_specs(cfg: ModelConfig):
    return dict(norm=(None,), w_up=("fsdp", "state"),
                wq=("heads", None, "state"), wk=("heads", None, "state"),
                wv=("heads", None, "state"),
                wif=("fsdp", None, None), bif=(None, None),
                wo=("heads", "state", "fsdp"))


def _mlstm_parallel(q, k, v, logi, logf, chunk: int = 1024):
    """Stabilized quadratic mLSTM, scanned over query chunks.

    q,k,v (B,S,H,dh); logi/logf (B,S,H).  Returns (B,S,H,dh) fp32.
    """
    b, s, h, dh = q.shape
    scale = 1.0 / (dh ** 0.5)
    cumf = jnp.cumsum(logf, axis=1)                     # (B,S,H)
    chunk = min(chunk, max(-(-s // 128) * 128, 128))   # no padding waste
    nq = -(-s // chunk)
    s_pad = nq * chunk
    padq = lambda x: jnp.pad(
        x, ((0, 0), (0, s_pad - s)) + ((0, 0),) * (x.ndim - 2))
    qc = padq(q).reshape(b, nq, chunk, h, dh).transpose(1, 0, 2, 3, 4)
    cumf_c = padq(cumf).reshape(b, nq, chunk, h).transpose(1, 0, 2, 3)
    # key-side term: log i_j - F_j  (B,S,H)
    kterm = logi - cumf

    # §Perf iteration 5: 4 mLSTM heads cannot shard a 16-way 'model'
    # axis — without sequence parallelism every model shard recomputes
    # the full (B,c,S,H) decay matrix (measured useful-ratio 0.06 on
    # prefill_32k).  Shard the query-chunk rows over 'model' instead.
    from repro.models.common import heads_tp_available
    seq_par = not heads_tp_available(h)

    def body(_, args):
        i, qi, cfi = args                               # (B,c,H,dh),(B,c,H)
        if seq_par:
            qi = shard(qi, "batch", "seq", None, None)
            cfi = shard(cfi, "batch", "seq", None)
        # logD_ij = F_i + (log i_j - F_j), masked to j <= i_abs
        logd = cfi[:, :, None, :] + kterm[:, None, :, :]   # (B,c,S,H)
        if seq_par:
            logd = shard(logd, "batch", "seq", None, None)
        jpos = jnp.arange(s)[None, None, :, None]
        ipos = (i * chunk + jnp.arange(chunk))[None, :, None, None]
        logd = jnp.where(jpos <= ipos, logd, -jnp.inf)
        m = jnp.max(logd, axis=2, keepdims=True)        # (B,c,1,H)
        m = jnp.where(jnp.isfinite(m), m, 0.0)
        dmat = jnp.exp(logd - m)
        sc = jnp.einsum("bchd,bshd->bcsh", qi.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
        sd = sc * dmat
        norm = jnp.maximum(jnp.abs(jnp.sum(sd, axis=2)),
                           jnp.exp(-m[:, :, 0, :]))     # (B,c,H)
        # §Perf iter 1: the decay-weighted score matrix crosses HBM in
        # bf16 (normalizer stats stay fp32) — score-sized traffic is
        # the dominant roofline term of the mLSTM parallel form.
        pdt = cm._probs_dtype()
        out = jnp.einsum("bcsh,bshd->bchd", sd.astype(pdt),
                         v.astype(pdt),
                         preferred_element_type=jnp.float32)
        return None, out / norm[..., None]

    _, oc = jax.lax.scan(body, None, (jnp.arange(nq), qc, cumf_c))
    o = oc.transpose(1, 0, 2, 3, 4).reshape(b, s_pad, h, dh)
    return o[:, :s]


def _mlstm_proj(cfg: ModelConfig, p, z):
    """Shared projection path: up-project, per-head q/k/v, gates."""
    dt = cfg.dtype
    d_in, h, dh = _dims(cfg)
    up = z @ p["w_up"].astype(dt)                        # (..., 2*d_in)
    xa, zg = jnp.split(up, 2, axis=-1)
    xh = xa.reshape(*xa.shape[:-1], h, dh)
    q = jnp.einsum("...hk,hkl->...hl", xh, p["wq"].astype(dt))
    k = jnp.einsum("...hk,hkl->...hl", xh, p["wk"].astype(dt))
    v = jnp.einsum("...hk,hkl->...hl", xh, p["wv"].astype(dt))
    gates = jnp.einsum("...d,dhg->...hg", z.astype(jnp.float32),
                       p["wif"].astype(jnp.float32)) + p["bif"]
    logi = gates[..., 0]                                 # log input gate
    logf = jax.nn.log_sigmoid(gates[..., 1])             # log forget gate
    return q, k, v, zg, logi, logf


def mlstm_fwd(cfg: ModelConfig, p, x):
    dt = cfg.dtype
    z = cm.rmsnorm(x, p["norm"], cfg.norm_eps)
    q, k, v, zg, logi, logf = _mlstm_proj(cfg, p, z)
    v = shard(v, "batch", None, "heads", None)
    o = _mlstm_parallel(q, k, v, logi, logf)
    g = jax.nn.silu(zg)
    b, s, _, _ = o.shape
    o = o.astype(dt) * g.reshape(b, s, cfg.n_heads, -1)
    return x + jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt))


def init_mlstm_state(cfg: ModelConfig, batch: int):
    _, h, dh = _dims(cfg)
    return dict(C=jnp.zeros((batch, h, dh, dh), jnp.float32),
                n=jnp.zeros((batch, h, dh), jnp.float32),
                m=jnp.full((batch, h), -1e30, jnp.float32))


def mlstm_step(cfg: ModelConfig, p, state, x):
    """x (B,d) one token; recurrent O(1) update."""
    dt = cfg.dtype
    z = cm.rmsnorm(x, p["norm"], cfg.norm_eps)
    q, k, v, zg, logi, logf = _mlstm_proj(cfg, p, z)
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    dh = q.shape[-1]
    m_new = jnp.maximum(logf + state["m"], logi)
    fp = jnp.exp(logf + state["m"] - m_new)[..., None]
    ip = jnp.exp(logi - m_new)[..., None]
    n = fp * state["n"] + ip * k
    C = (fp[..., None] * state["C"]
         + ip[..., None] * v[..., :, None] * k[..., None, :])
    denom = jnp.maximum(jnp.abs(jnp.sum(n * q, -1)), jnp.exp(-m_new))
    o = jnp.einsum("bhvk,bhk->bhv", C, q / (dh ** 0.5)) / denom[..., None]
    g = jax.nn.silu(zg).astype(jnp.float32)
    o = o * g.reshape(g.shape[0], cfg.n_heads, -1)
    y = x + jnp.einsum("bhk,hkd->bd", o.astype(dt), p["wo"].astype(dt))
    return dict(C=C, n=n, m=m_new), y


# ---------------------------------------------------------------------------
# sLSTM block


def _sdims(cfg: ModelConfig):
    """sLSTM operates at d_model width (official block shape)."""
    return cfg.d_model, cfg.n_heads, cfg.d_model // cfg.n_heads


def init_slstm(cfg: ModelConfig, rng, scale: float):
    d = cfg.d_model
    d_in, h, dh = _sdims(cfg)
    ks = jax.random.split(rng, 4)
    return dict(
        norm=jnp.ones((d,), jnp.float32),
        wx=jax.random.normal(ks[0], (d, 4, d_in), jnp.float32) * scale,
        # recurrent mixing is block-diagonal per head
        rh=jax.random.normal(ks[1], (h, dh, 4, dh), jnp.float32) * scale,
        b=jnp.zeros((4, d_in), jnp.float32),
        wo=jax.random.normal(ks[2], (d_in, d), jnp.float32) * scale,
    )


def slstm_specs(cfg: ModelConfig):
    return dict(norm=(None,), wx=("fsdp", None, "state"),
                rh=("heads", None, None, "state"), b=(None, "state"),
                wo=("state", "fsdp"), )


def init_slstm_state(cfg: ModelConfig, batch: int):
    d_in, h, dh = _sdims(cfg)
    z = jnp.zeros((batch, d_in), jnp.float32)
    return dict(c=z, n=z, h=z,
                m=jnp.full((batch, d_in), -1e30, jnp.float32))


def _slstm_cell(cfg: ModelConfig, p, state, xt):
    """xt (B, 4, d_in) precomputed input contributions."""
    _, h_heads, dh = _sdims(cfg)
    b = xt.shape[0]
    hprev = state["h"].reshape(b, h_heads, dh)
    rec = jnp.einsum("bhk,hkgl->bhgl", hprev,
                     p["rh"].astype(jnp.float32)).reshape(b, 4, -1)
    za, ia, fa, oa = jnp.moveaxis(
        xt + rec + p["b"].astype(jnp.float32), 1, 0)
    z = jnp.tanh(za)
    o = jax.nn.sigmoid(oa)
    logi, logf = ia, jax.nn.log_sigmoid(fa)
    m_new = jnp.maximum(logf + state["m"], logi)
    fp = jnp.exp(logf + state["m"] - m_new)
    ip = jnp.exp(logi - m_new)
    c = fp * state["c"] + ip * z
    n = fp * state["n"] + ip
    hnew = o * c / jnp.maximum(n, 1.0)
    return dict(c=c, n=n, h=hnew, m=m_new), hnew


def slstm_fwd(cfg: ModelConfig, p, x):
    """Sequential over time (inherent to sLSTM).  x (B,S,d)."""
    b, s, d = x.shape
    z = cm.rmsnorm(x, p["norm"], cfg.norm_eps)
    xg = jnp.einsum("bsd,dgk->sbgk", z.astype(jnp.float32),
                    p["wx"].astype(jnp.float32))
    state = init_slstm_state(cfg, b)

    def body(st, xt):
        st, h = _slstm_cell(cfg, p, st, xt)
        return st, h

    _, hs = jax.lax.scan(body, state, xg)
    hs = hs.transpose(1, 0, 2).astype(cfg.dtype)        # (B,S,d_in)
    return x + hs @ p["wo"].astype(cfg.dtype)


def slstm_step(cfg: ModelConfig, p, state, x):
    z = cm.rmsnorm(x, p["norm"], cfg.norm_eps)
    xg = jnp.einsum("bd,dgk->bgk", z.astype(jnp.float32),
                    p["wx"].astype(jnp.float32))
    state, h = _slstm_cell(cfg, p, state, xg)
    return state, x + (h.astype(cfg.dtype) @ p["wo"].astype(cfg.dtype))


# ---------------------------------------------------------------------------
# full model


def _segments(cfg: ModelConfig):
    per = cfg.slstm_every
    assert cfg.n_layers % per == 0, (cfg.n_layers, per)
    return cfg.n_layers // per, per - 1


def init_params(cfg: ModelConfig, rng):
    n_seg, n_m = _segments(cfg)
    k_emb, k_m, k_s = jax.random.split(rng, 3)
    scale = 0.02 / (2 * cfg.n_layers) ** 0.5
    from repro.models.transformer import stack_layers
    return dict(
        embed=cm.init_embedding(cfg, k_emb),
        mlstm=stack_layers(lambda r: init_mlstm(cfg, r, scale), k_m,
                           n_seg * n_m),
        slstm=stack_layers(lambda r: init_slstm(cfg, r, scale), k_s, n_seg),
    )


def param_specs(cfg: ModelConfig):
    from repro.models.transformer import stacked_specs
    return dict(embed=cm.embedding_specs(cfg),
                mlstm=stacked_specs(mlstm_specs(cfg)),
                slstm=stacked_specs(slstm_specs(cfg)))


def forward(cfg: ModelConfig, params, tokens):
    n_seg, n_m = _segments(cfg)
    x = cm.embed(cfg, params["embed"], tokens)
    mparams = jax.tree_util.tree_map(
        lambda a: a.reshape(n_seg, n_m, *a.shape[1:]),
        cm.cast_params(cfg, params["mlstm"]))

    @jax.checkpoint
    def mbody(x, lp):
        return mlstm_fwd(cfg, lp, x), None

    for seg in range(n_seg):
        seg_p = jax.tree_util.tree_map(lambda a: a[seg], mparams)
        x, _ = jax.lax.scan(mbody, x, seg_p)
        x = slstm_fwd(cfg, jax.tree_util.tree_map(
            lambda a: a[seg], params["slstm"]), x)
    return cm.logits(cfg, params["embed"], x)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int = 0):
    """Recurrent state — O(1) in sequence length (the 500k cell)."""
    n_seg, n_m = _segments(cfg)
    rep = lambda st, n: jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (n,) + a.shape), st)
    return dict(mlstm=rep(init_mlstm_state(cfg, batch), n_seg * n_m),
                slstm=rep(init_slstm_state(cfg, batch), n_seg),
                length=jnp.zeros((batch,), jnp.int32))


def cache_specs(cfg: ModelConfig, *, shard_seq: bool = True):
    return dict(
        mlstm=dict(C=(None, "batch", "heads", "state", None),
                   n=(None, "batch", "heads", None),
                   m=(None, "batch", "heads")),
        slstm=dict(c=(None, "batch", "state"), n=(None, "batch", "state"),
                   h=(None, "batch", "state"), m=(None, "batch", "state")),
        length=(None,))


def decode_step(cfg: ModelConfig, params, cache, tokens):
    n_seg, n_m = _segments(cfg)
    x = cm.embed(cfg, params["embed"], tokens[:, None])[:, 0]
    mstates = cache["mlstm"]

    def mbody(x, scan_in):
        lp, st = scan_in
        st, x = mlstm_step(cfg, lp, st, x)
        return x, st

    new_m, new_s = [], []
    mp = jax.tree_util.tree_map(
        lambda a: a.reshape(n_seg, n_m, *a.shape[1:]), params["mlstm"])
    ms = jax.tree_util.tree_map(
        lambda a: a.reshape(n_seg, n_m, *a.shape[1:]), mstates)
    for seg in range(n_seg):
        x, st_out = jax.lax.scan(
            mbody, x, (jax.tree_util.tree_map(lambda a: a[seg], mp),
                       jax.tree_util.tree_map(lambda a: a[seg], ms)))
        new_m.append(st_out)
        sp = jax.tree_util.tree_map(lambda a: a[seg], params["slstm"])
        sst = jax.tree_util.tree_map(lambda a: a[seg], cache["slstm"])
        sst, x = slstm_step(cfg, sp, sst, x)
        new_s.append(sst)
    out = cm.logits(cfg, params["embed"], x[:, None])[:, 0]
    stackf = lambda lst: jax.tree_util.tree_map(
        lambda *a: jnp.stack(a), *lst)
    cat_m = jax.tree_util.tree_map(
        lambda a: a.reshape(n_seg * n_m, *a.shape[2:]), stackf(new_m))
    return out, dict(mlstm=cat_m, slstm=stackf(new_s),
                     length=cache["length"] + 1)
