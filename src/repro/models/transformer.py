"""Decoder-only GQA transformer (tinyllama / minitron / qwen2 / deepseek
families) and the shared block machinery reused by the MoE / VLM /
audio variants.

Layers are stacked along a leading L axis and consumed by `lax.scan`
with `jax.checkpoint` around the block — one compact While loop in HLO
regardless of depth, with one saved residual per layer (the remat
policy the §Perf log iterates on).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.common import ModelConfig
from repro.parallel.axes import shard


# ---------------------------------------------------------------------------
# params


def init_block(cfg: ModelConfig, rng, mlp_init=None):
    """One decoder block; callers vmap this over layer seeds to stack."""
    k1, k2 = jax.random.split(rng)
    scale = 0.02 / (2 * cfg.n_layers) ** 0.5
    mlp_init = mlp_init or (lambda r: cm.init_mlp(cfg, r, scale))
    return dict(
        norm1=jnp.ones((cfg.d_model,), jnp.float32),
        attn=cm.init_attn(cfg, k1, scale),
        norm2=jnp.ones((cfg.d_model,), jnp.float32),
        mlp=mlp_init(k2),
    )


def block_specs(cfg: ModelConfig, mlp_spec=None):
    """Spec tree for one block; leading 'layers' dim added by stack()."""
    return dict(norm1=(None,), attn=cm.attn_specs(cfg), norm2=(None,),
                mlp=mlp_spec or cm.mlp_specs())


def stack_layers(init_one, rng, n_layers: int):
    """vmap a per-layer init over seeds -> stacked (L, ...) params."""
    return jax.vmap(init_one)(jax.random.split(rng, n_layers))


def stacked_specs(spec_tree):
    """Prepend the (unsharded) layer axis to every leaf of a spec tree."""
    return jax.tree_util.tree_map(
        lambda t: (None,) + t, spec_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(n, (str, type(None))) for n in x))


def init_params(cfg: ModelConfig, rng, mlp_init=None):
    k_emb, k_layers = jax.random.split(rng)
    return dict(
        embed=cm.init_embedding(cfg, k_emb),
        layers=stack_layers(
            lambda r: init_block(cfg, r, mlp_init), k_layers, cfg.n_layers),
    )


def param_specs(cfg: ModelConfig, mlp_spec=None):
    return dict(embed=cm.embedding_specs(cfg),
                layers=stacked_specs(block_specs(cfg, mlp_spec)))


# ---------------------------------------------------------------------------
# forward (training / prefill)


def _residual_spec():
    """Residual-stream sharding (REPRO_SP_RESIDUAL=1: Megatron-style
    sequence parallelism — norms/residual ops run on seq shards over
    the 'model' axis; §Perf A/B knob)."""
    import os
    if os.environ.get("REPRO_SP_RESIDUAL"):
        return ("batch", "seq", None)
    return ("batch", None, None)


def block_fwd(cfg: ModelConfig, p, x, positions, mlp_fn=None):
    h = cm.rmsnorm(x, p["norm1"], cfg.norm_eps)
    x = x + cm.self_attention(cfg, p["attn"], h, positions)
    h = cm.rmsnorm(x, p["norm2"], cfg.norm_eps)
    x = x + (mlp_fn or functools.partial(cm.mlp, cfg))(p["mlp"], h)
    return shard(x, *_residual_spec())


def _remat():
    """Per-layer remat policy (REPRO_REMAT_POLICY: full|dots — §Perf
    A/B knob).  'full' saves one residual per layer and recomputes the
    block in bwd; 'dots' additionally saves matmul outputs (no
    attention recompute, more saved activations)."""
    import functools
    import os
    if os.environ.get("REPRO_REMAT_POLICY") == "dots":
        return functools.partial(
            jax.checkpoint,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint


def forward(cfg: ModelConfig, params, tokens, mlp_fn=None):
    """tokens (B, S) -> logits (B, S, V)."""
    x = cm.embed(cfg, params["embed"], tokens)
    positions = jnp.arange(tokens.shape[1])[None, :]

    @_remat()
    def body(x, layer_p):
        return block_fwd(cfg, layer_p, x, positions, mlp_fn), None

    x, _ = jax.lax.scan(body, x, cm.cast_params(cfg, params["layers"]))
    return cm.logits(cfg, params["embed"], x)


# ---------------------------------------------------------------------------
# KV cache serving


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None):
    dt = dtype or cfg.dtype
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return dict(k=jnp.zeros(shape, dt), v=jnp.zeros(shape, dt),
                length=jnp.zeros((batch,), jnp.int32))


def cache_specs(cfg: ModelConfig, *, shard_seq: bool = True):
    """KV sharded (batch, seq, kv-heads) by the dedup rules: the seq
    dim takes whatever mesh axes the batch dim leaves free —
    flash-decoding split-KV over 'model' for batched decode, full
    ('data','model') seq sharding for the batch=1 long-context cell.
    The partial-softmax combine lowers to small all-reduces, see
    `attention_over_cache`."""
    kv = (None, "batch", "kv_seq" if shard_seq else None, "kv_heads", None)
    return dict(k=kv, v=kv, length=(None,))


def attention_over_cache(cfg: ModelConfig, q, ck, cv, lengths):
    """Decode attention: q (B,Sq,Hq,D) over cache (B,T,Hkv,D).

    Grouped GQA (no repeated-KV materialization) and written
    max/sum-explicitly so that when the cache is sequence-sharded,
    SPMD turns the reductions into the flash-decoding combine
    (all-reduce of per-shard partial max/denominator/output) instead
    of an all-gather of the KV cache.
    """
    b, sq, hq, d = q.shape
    t, hkv = ck.shape[1], ck.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    scale = 1.0 / (d ** 0.5)
    s = jnp.einsum("bqhgd,bthd->bqhgt", qg.astype(jnp.float32),
                   ck.astype(jnp.float32)) * scale
    if cfg.attn_logit_softcap > 0.0:
        s = cfg.attn_logit_softcap * jnp.tanh(s / cfg.attn_logit_softcap)
    valid = (jnp.arange(t)[None, :]
             < lengths[:, None])[:, None, None, None, :]
    s = jnp.where(valid, s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    o = jnp.einsum("bqhgt,bthd->bqhgd", p, cv.astype(jnp.float32))
    o = o / jnp.sum(p, axis=-1)[..., None]
    return o.reshape(b, sq, hq, d).astype(q.dtype)


def decode_block(cfg: ModelConfig, p, kv, x, lengths, mlp_fn=None):
    """One block, one new token.  x (B,1,d); kv dict of (B,T,Hkv,D)."""
    h = cm.rmsnorm(x, p["norm1"], cfg.norm_eps)
    q, k_new, v_new = cm.attn_qkv(cfg, p["attn"], h, lengths[:, None])
    # write the new KV at each sequence's current length
    upd = lambda c, n: jax.vmap(
        lambda cb, nb, lb: jax.lax.dynamic_update_slice_in_dim(
            cb, nb.astype(cb.dtype), lb, axis=0))(c, n, lengths)
    # Pin the cache layout: without this, SPMD back-propagates the
    # head-sharded attention-output layout into the cache and moves
    # the WHOLE cache across the mesh every layer (measured 11.8 GB
    # of collective-permute per decode step on tinyllama/decode_32k).
    pin = lambda c: shard(c, "batch", "kv_seq", "kv_heads", None)
    kv = dict(k=pin(upd(kv["k"], k_new)), v=pin(upd(kv["v"], v_new)))
    o = attention_over_cache(cfg, q, kv["k"], kv["v"], lengths + 1)
    x = x + cm.attn_out(cfg, p["attn"], o)
    h = cm.rmsnorm(x, p["norm2"], cfg.norm_eps)
    x = x + (mlp_fn or functools.partial(cm.mlp, cfg))(p["mlp"], h)
    return kv, x


def decode_step(cfg: ModelConfig, params, cache, tokens, mlp_fn=None):
    """One decode step.  tokens (B,) -> (logits (B,V), cache')."""
    x = cm.embed(cfg, params["embed"], tokens[:, None])
    lengths = cache["length"]

    def body(x, scan_in):
        layer_p, kv = scan_in
        kv, x = decode_block(cfg, layer_p, kv, x, lengths, mlp_fn)
        return x, kv

    x, kv = jax.lax.scan(
        body, x, (params["layers"], dict(k=cache["k"], v=cache["v"])))
    out = cm.logits(cfg, params["embed"], x)[:, 0]
    return out, dict(k=kv["k"], v=kv["v"], length=lengths + 1)


def prefill(cfg: ModelConfig, params, tokens, max_seq: int | None = None,
            mlp_fn=None):
    """Prefill: forward + populate a KV cache.  tokens (B, S)."""
    b, s = tokens.shape
    t = max_seq or s
    x = cm.embed(cfg, params["embed"], tokens)
    positions = jnp.arange(s)[None, :]

    def body(x, layer_p):
        h = cm.rmsnorm(x, layer_p["norm1"], cfg.norm_eps)
        q, k, v = cm.attn_qkv(cfg, layer_p["attn"], h, positions)
        o = cm.attention(cfg, q, k, v, causal=True)
        x = x + cm.attn_out(cfg, layer_p["attn"], o)
        h = cm.rmsnorm(x, layer_p["norm2"], cfg.norm_eps)
        x = x + (mlp_fn or functools.partial(cm.mlp, cfg))(layer_p["mlp"], h)
        pad = ((0, 0), (0, t - s), (0, 0), (0, 0))
        return shard(x, "batch", None, None), dict(
            k=jnp.pad(k, pad), v=jnp.pad(v, pad))

    x, kv = jax.lax.scan(body, x, params["layers"])
    logit = cm.logits(cfg, params["embed"], x)
    cache = dict(k=kv["k"], v=kv["v"],
                 length=jnp.full((b,), s, jnp.int32))
    return logit, cache
