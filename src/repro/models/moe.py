"""Mixture-of-Experts FFN (arctic-480b, grok-1-314b).

GShard-style top-k dispatch with capacity, formulated as einsums over a
one-hot dispatch tensor so the whole layer is pure pjit-able dataflow
(no data-dependent shapes, differentiable, SPMD-shardable):

* tokens are processed in groups of ``cfg := moe_group`` (dispatch
  memory is (groups, G, E, C) with C = G*k*cf/E — bounded per group),
* expert weights carry logical axes ('experts', 'fsdp', 'mlp').  Under
  the divisibility+dedup rules this yields **EP** when E divides the
  'model' axis (arctic: 128/16 -> 8 experts/shard) and falls back to
  **TP within experts** when it does not (grok: 8 experts on a 16-way
  axis -> d_ff 32768/16 sharded) — no per-arch code.
* overflowed tokens (beyond capacity) are dropped, standard GShard
  semantics; the router adds the load-balancing auxiliary loss.

Arctic's "dense residual": a small dense SwiGLU runs in parallel with
the MoE and both add into the residual stream.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.common import ModelConfig
from repro.parallel.axes import (_mesh, resolve, serving_mode, shard)

MOE_GROUP = 2048          # dispatch group size (tokens)


def init_moe(cfg: ModelConfig, rng, scale: float):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(rng, 5)
    p = dict(
        router=jax.random.normal(ks[0], (d, e), jnp.float32) * 0.02,
        we_gate=jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale,
        we_up=jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale,
        we_down=jax.random.normal(ks[3], (e, f, d), jnp.float32) * scale,
    )
    if cfg.dense_residual:
        p["dense"] = cm.init_mlp(cfg, ks[4], scale)
    return p


def moe_specs(cfg: ModelConfig):
    p = dict(router=(None, None),
             we_gate=("experts", "fsdp", "mlp"),
             we_up=("experts", "fsdp", "mlp"),
             we_down=("experts", "mlp", "fsdp"))
    if cfg.dense_residual:
        p["dense"] = cm.mlp_specs()
    return p


def _capacity(cfg: ModelConfig, group: int) -> int:
    c = int(group * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(c, cfg.top_k)


def moe_mlp(cfg: ModelConfig, p, x):
    """x (B, S, d) -> (B, S, d), plus stores aux loss via jnp (returned).

    Returns (y, aux_loss) — callers inside residual blocks use
    `moe_mlp_y` which drops the aux term (it is re-computed by the
    train loss through `router_stats` if needed).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    g = min(MOE_GROUP, s)
    ng = s // g
    assert s % g == 0, (s, g)
    c = _capacity(cfg, g)
    xg = x.reshape(b, ng, g, d)
    xg = shard(xg, "batch", None, None, None)

    logit = jnp.einsum("bngd,de->bnge", xg.astype(jnp.float32),
                       p["router"].astype(jnp.float32))
    gates = jax.nn.softmax(logit, axis=-1)               # (B,ng,G,E)

    # iterative top-k with positional (capacity) assignment
    remaining = gates
    dispatch = jnp.zeros((b, ng, g, e, c), cfg.dtype)
    combine = jnp.zeros((b, ng, g, e, c), jnp.float32)
    fill = jnp.zeros((b, ng, e), jnp.int32)              # used capacity
    gate_sum = jnp.zeros((b, ng, g), jnp.float32)
    for _ in range(k):
        idx = jnp.argmax(remaining, axis=-1)             # (B,ng,G)
        mask = jax.nn.one_hot(idx, e, dtype=jnp.float32)
        gval = jnp.sum(remaining * mask, axis=-1)        # (B,ng,G)
        remaining = remaining * (1.0 - mask)
        pos = (jnp.cumsum(mask, axis=2) - mask            # pos within group
               + fill[:, :, None, :].astype(jnp.float32))
        slot = jnp.sum(pos * mask, axis=-1)              # (B,ng,G)
        ok = (slot < c) & (gval > 0)
        slot_oh = jax.nn.one_hot(slot, c, dtype=jnp.float32) \
            * ok[..., None].astype(jnp.float32)
        d_k = mask[..., None] * slot_oh[..., None, :]    # (B,ng,G,E,C)
        dispatch = dispatch + d_k.astype(cfg.dtype)
        combine = combine + d_k * gval[..., None, None]
        gate_sum = gate_sum + gval * ok.astype(jnp.float32)
        fill = fill + jnp.sum(mask * ok[..., None].astype(jnp.float32),
                              axis=2).astype(jnp.int32)

    combine = combine / jnp.maximum(gate_sum, 1e-9)[..., None, None]

    # dispatch -> expert FFN -> combine
    xe = jnp.einsum("bngec,bngd->bnecd", dispatch, xg)
    dt = cfg.dtype
    if serving_mode() and _mesh() is not None:
        ye = _expert_ffn_weight_stationary(cfg, p, xe)
    else:
        xe = shard(xe, "batch", None, "experts", None, None)
        h = (jax.nn.silu(jnp.einsum("bnecd,edf->bnecf", xe,
                                    p["we_gate"].astype(dt)))
             * jnp.einsum("bnecd,edf->bnecf", xe, p["we_up"].astype(dt)))
        h = shard(h, "batch", None, "experts", None, "mlp")
        ye = jnp.einsum("bnecf,efd->bnecd", h, p["we_down"].astype(dt))
    y = jnp.einsum("bngec,bnecd->bngd", combine.astype(dt), ye)
    y = y.reshape(b, s, d)

    # GShard load-balancing aux loss
    me = jnp.mean(gates, axis=(0, 1, 2))                  # (E,)
    top1 = jax.nn.one_hot(jnp.argmax(gates, -1), e, dtype=jnp.float32)
    fe = jnp.mean(top1, axis=(0, 1, 2))
    aux = e * jnp.sum(me * fe)

    if cfg.dense_residual:
        y = y + cm.mlp(cfg, p["dense"], x)
    return y, aux


def _expert_ffn_weight_stationary(cfg: ModelConfig, p, xe):
    """Serving path (§Perf iteration 2): weight-stationary expert FFN.

    At decode, XLA's SPMD heuristic resolves the expert einsums by
    ALL-GATHERING the expert weights over the fsdp axis — ~58 GB/step
    for arctic-480b (measured; the dominant collective term).  This
    shard_map fixes the schedule deterministically: expert weights stay
    resident in their (experts->model, hidden->data) shards, the tiny
    decode activations are replicated in, each device computes its
    hidden-dim partial, and the down-projection partials are psum'd
    over the hidden-shard axes.  Bytes moved per layer drop from
    O(expert weights) to O(decode activations).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = _mesh()
    dt = cfg.dtype

    wg_spec = resolve(moe_specs(cfg)["we_gate"], p["we_gate"].shape)
    wd_spec = resolve(moe_specs(cfg)["we_down"], p["we_down"].shape)
    e_axes = wg_spec[0] if len(wg_spec) > 0 else None       # experts
    f_axes = wd_spec[1] if len(wd_spec) > 1 else None       # hidden
    flat = lambda a: (() if a is None
                      else (a,) if isinstance(a, str) else tuple(a))
    psum_axes = flat(f_axes)

    xe_spec = P(None, None, e_axes, None, None)

    def local(xe_l, wg_l, wu_l, wd_l):
        h = (jax.nn.silu(jnp.einsum("bnecd,edf->bnecf", xe_l,
                                    wg_l.astype(dt)))
             * jnp.einsum("bnecd,edf->bnecf", xe_l, wu_l.astype(dt)))
        ye = jnp.einsum("bnecf,efd->bnecd", h, wd_l.astype(dt))
        if psum_axes:
            ye = jax.lax.psum(ye, psum_axes)
        return ye

    return shard_map(
        local, mesh=mesh,
        in_specs=(xe_spec, wg_spec, wg_spec, wd_spec),
        out_specs=xe_spec,
        check_rep=False,
    )(xe.astype(dt), p["we_gate"], p["we_up"], p["we_down"])


def moe_mlp_y(cfg: ModelConfig, p, x):
    return moe_mlp(cfg, p, x)[0]
