"""Deterministic synthetic LM data pipeline.

A hash-based token stream (splitmix-style) with a learnable structure:
token t+1 depends on token t through a fixed random permutation mixed
with noise, so a real model shows decreasing loss — useful for the
end-to-end training example, where "loss goes down" is the check.

Properties needed at scale and provided here:

* **deterministic + seekable** — batch `i` is a pure function of
  (seed, i), so a restart resumes the stream exactly at the checkpoint
  step with no data replay or skew;
* **host-sharded** — each host materializes only its slice of the
  global batch (`host_slice`), matching jax.make_array_from_callback
  in the multi-host launcher;
* **packed** — documents are length-`seq+1` windows; `tokens`/`labels`
  are the usual shift-by-one views.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int = 32000
    seq_len: int = 1024
    global_batch: int = 8
    seed: int = 0
    structure: float = 0.8     # P(next token = perm[cur]) vs uniform


def _splitmix(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    z = x
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


def _perm(cfg: DataConfig) -> np.ndarray:
    rng = np.random.default_rng(cfg.seed + 7)
    return rng.permutation(cfg.vocab)


def batch_at(cfg: DataConfig, index: int,
             host_slice: slice | None = None) -> dict:
    """The `index`-th global batch (or one host's slice of it)."""
    sl = host_slice or slice(0, cfg.global_batch)
    rows = np.arange(sl.start, sl.stop, dtype=np.uint64)
    perm = _perm(cfg)
    n = cfg.seq_len + 1
    base = (np.uint64(index) * np.uint64(cfg.global_batch * 131)
            + rows * np.uint64(1313) + np.uint64(cfg.seed) << np.uint64(20))
    toks = np.empty((len(rows), n), np.int64)
    toks[:, 0] = (_splitmix(base) % np.uint64(cfg.vocab)).astype(np.int64)
    for t in range(1, n):
        h = _splitmix(base + np.uint64(t))
        coin = (h & np.uint64(0xFFFF)).astype(np.float64) / 65535.0
        rnd = ((h >> np.uint64(16)) % np.uint64(cfg.vocab)).astype(np.int64)
        follow = perm[toks[:, t - 1]]
        toks[:, t] = np.where(coin < cfg.structure, follow, rnd)
    return dict(tokens=toks[:, :-1].astype(np.int32),
                labels=toks[:, 1:].astype(np.int32))


class Stream:
    """Seekable iterator over batches (resume with `seek`)."""

    def __init__(self, cfg: DataConfig, host_slice: slice | None = None,
                 start: int = 0):
        self.cfg = cfg
        self.host_slice = host_slice
        self.index = start

    def seek(self, index: int):
        self.index = index

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        b = batch_at(self.cfg, self.index, self.host_slice)
        self.index += 1
        return b
