"""Fault tolerance for 1000+-node runs.

Three mechanisms, all exercised by tests on this single host and
designed to scale by construction:

* **Preemption hook** — SIGTERM/SIGINT set a flag; the training loop
  checkpoints at the next step boundary and exits cleanly.  On cloud
  TPU pods this is the maintenance-event path.
* **Straggler detection** — per-step wall-clock watchdog.  A step that
  exceeds ``timeout_factor x`` the trailing-median step time is flagged;
  after ``max_flags`` consecutive flags the runner requests a restart
  (on a real cluster: evict the slow host and re-mesh).  Detection is
  host-side and free — it never blocks the device stream.
* **Elastic re-mesh** — `plan_elastic_mesh` recomputes the largest
  usable (data, model) mesh from the devices that remain after a
  failure (keeping 'model' intact, shrinking 'data'), so training
  resumes from the last checkpoint with a smaller data-parallel width
  instead of dying.  Param shardings are re-derived from the same
  logical specs — nothing about the model code changes.
"""
from __future__ import annotations

import dataclasses
import signal
import statistics
import time


class PreemptionGuard:
    """Signal-driven graceful-shutdown flag."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._requested = False
        self._prev = {}
        self._signals = signals

    def __enter__(self):
        for s in self._signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc):
        for s, h in self._prev.items():
            signal.signal(s, h)
        return False

    def _handler(self, signum, frame):
        self._requested = True

    @property
    def preempted(self) -> bool:
        return self._requested


@dataclasses.dataclass
class StragglerWatchdog:
    timeout_factor: float = 3.0
    max_flags: int = 3
    window: int = 32
    _times: list = dataclasses.field(default_factory=list)
    _flags: int = 0

    def observe(self, step_seconds: float) -> bool:
        """Record a step time; True if a restart should be requested."""
        if len(self._times) >= 8:
            med = statistics.median(self._times[-self.window:])
            if step_seconds > self.timeout_factor * med:
                self._flags += 1
            else:
                self._flags = 0
        self._times.append(step_seconds)
        del self._times[:-self.window]
        return self._flags >= self.max_flags

    def timer(self):
        return _StepTimer(self)


class _StepTimer:
    def __init__(self, dog):
        self.dog = dog

    def __enter__(self):
        self.t0 = time.monotonic()
        return self

    def __exit__(self, *exc):
        self.flagged = self.dog.observe(time.monotonic() - self.t0)
        return False


def plan_elastic_mesh(n_alive: int, model_size: int,
                      pod_size: int | None = None) -> tuple:
    """Largest (pod, data, model) shape from `n_alive` devices.

    Keeps the 'model' axis intact (TP groups must be complete) and
    shrinks 'data' (losing data-parallel replicas only).  Returns the
    mesh shape tuple; raises if not even one model group survives.
    """
    if n_alive < model_size:
        raise RuntimeError(
            f"only {n_alive} devices alive; need >= one model group "
            f"of {model_size}")
    data = n_alive // model_size
    if pod_size is not None and data * model_size > pod_size:
        pods = (data * model_size) // pod_size
        data_per_pod = pod_size // model_size
        return (pods, data_per_pod, model_size)
    return (data, model_size)
