"""AdamW, built from scratch (no optax in the environment).

Production knobs:

* ``state_dtype`` — Adam moments can be held in bf16 for the giant MoE
  archs (arctic-480b / grok-1-314b), where fp32 m+v would not fit
  16 GB/chip even fully sharded; see DESIGN.md §memory budget.
* global-norm gradient clipping,
* decoupled weight decay,
* linear warmup + cosine decay schedule.

Optimizer state is a pytree congruent with the params, so the same
param spec tree shards it (ZeRO: moments sharded exactly like params).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    state_dtype: Any = jnp.float32


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_state(cfg: AdamWConfig, params):
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return dict(
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def state_specs(param_specs_tree):
    """Moments shard exactly like the params (ZeRO)."""
    return dict(m=param_specs_tree, v=param_specs_tree, step=())


def global_norm(tree):
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree_util.tree_leaves(tree)))


def apply_updates(cfg: AdamWConfig, params, grads, state):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:     # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m32.astype(cfg.state_dtype), v32.astype(cfg.state_dtype)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = dict(grad_norm=gnorm, lr=lr)
    return new_p, dict(m=new_m, v=new_v, step=step), metrics
