"""The training loop: step + checkpoint + fault tolerance, assembled.

Single entry point used by `launch/train.py` and the examples.  The
loop is mesh-agnostic: with sharding rules installed (launcher) the
step is pjit-sharded; without (CPU smoke tests) it is a plain jit.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import ModelApi
from repro.parallel import compression
from repro.train import checkpoint as ckpt
from repro.train import fault_tolerance as ft
from repro.train import optimizer as opt
from repro.train.step import build_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str | None = None
    ckpt_keep: int = 3
    accum: int = 1
    z_loss: float = 0.0
    compress_grads: bool = False
    log_every: int = 10
    straggler_factor: float = 5.0


class Trainer:
    def __init__(self, api: ModelApi, opt_cfg: opt.AdamWConfig,
                 tcfg: TrainerConfig, *, rng=None,
                 log_fn: Callable[[str], None] = print):
        self.api = api
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.log = log_fn
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.params = api.init(rng)
        self.opt_state = opt.init_state(opt_cfg, self.params)
        self.step_idx = 0

        self._ef = (compression.init_error_feedback(self.params)
                    if tcfg.compress_grads else None)
        base_step = build_train_step(api, opt_cfg, accum=tcfg.accum,
                                     z_loss=tcfg.z_loss)
        if tcfg.compress_grads:
            from repro.train.step import build_loss_fn
            loss_fn = build_loss_fn(api, z_loss=tcfg.z_loss)
            grad_fn = jax.value_and_grad(loss_fn)

            def step_fn(params, opt_state, ef, batch):
                loss, grads = grad_fn(params, batch)
                grads, ef = compression.compress_decompress(grads, ef)
                params, opt_state, metrics = opt.apply_updates(
                    opt_cfg, params, grads, opt_state)
                return params, opt_state, ef, dict(metrics, loss=loss)

            self._jit_step = jax.jit(step_fn, donate_argnums=(0, 1, 2))
        else:
            self._jit_step = jax.jit(
                lambda p, s, b: base_step(p, s, b),
                donate_argnums=(0, 1))

    # -- checkpoint / resume -------------------------------------------------

    def state(self):
        return dict(params=self.params, opt=self.opt_state,
                    step=jnp.asarray(self.step_idx))

    def maybe_resume(self) -> bool:
        d = self.tcfg.ckpt_dir
        if not d:
            return False
        latest = ckpt.latest_step(d)
        if latest is None:
            return False
        state, _ = ckpt.restore(d, self.state())
        self.params = state["params"]
        self.opt_state = state["opt"]
        self.step_idx = int(state["step"])
        self.log(f"[trainer] resumed from step {self.step_idx}")
        return True

    def save(self):
        if not self.tcfg.ckpt_dir:
            return
        ckpt.save(self.tcfg.ckpt_dir, self.step_idx, self.state())
        ckpt.prune(self.tcfg.ckpt_dir, self.tcfg.ckpt_keep)

    # -- loop ----------------------------------------------------------------

    def fit(self, batches: Iterable[dict]) -> dict:
        tcfg = self.tcfg
        watchdog = ft.StragglerWatchdog(timeout_factor=tcfg.straggler_factor)
        losses = []
        it = iter(batches)
        with ft.PreemptionGuard() as guard:
            while self.step_idx < tcfg.total_steps:
                batch_np = next(it)
                batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
                t0 = time.monotonic()
                if tcfg.compress_grads:
                    (self.params, self.opt_state, self._ef,
                     metrics) = self._jit_step(
                        self.params, self.opt_state, self._ef, batch)
                else:
                    self.params, self.opt_state, metrics = self._jit_step(
                        self.params, self.opt_state, batch)
                loss = float(metrics["loss"])
                dt = time.monotonic() - t0
                losses.append(loss)
                self.step_idx += 1
                if watchdog.observe(dt):
                    self.log(f"[trainer] straggling at step "
                             f"{self.step_idx}; checkpoint + restart")
                    self.save()
                    break
                if self.step_idx % tcfg.log_every == 0:
                    self.log(f"[trainer] step {self.step_idx:5d} "
                             f"loss {loss:.4f} "
                             f"({dt * 1e3:.0f} ms/step)")
                if tcfg.ckpt_every and self.step_idx % tcfg.ckpt_every == 0:
                    self.save()
                if guard.preempted:
                    self.log("[trainer] preemption requested; "
                             "checkpointing and exiting")
                    self.save()
                    break
        return dict(losses=losses, final_step=self.step_idx)
