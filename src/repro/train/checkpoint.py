"""Sharded, atomic, resumable checkpointing.

Layout::

    <dir>/step_000100/
        manifest.json          # step, leaf paths, shapes, dtypes
        arrays/<flat.key>.npy  # one file per pytree leaf
    <dir>/LATEST               # text file naming the newest complete step

Write protocol (crash-safe): write into ``step_N.tmp/``, fsync,
atomic-rename to ``step_N/``, then rewrite LATEST.  A partially
written checkpoint can never be named by LATEST, so restart-from-latest
is always consistent — the fault-tolerance contract the trainer and the
preemption hook rely on.

On a real multi-host cluster each host writes only its addressable
shards and host 0 writes the manifest after a barrier; the single-host
code path here is the degenerate case of that protocol (documented in
DESIGN.md §fault-tolerance).
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import ml_dtypes
import numpy as np

#: numpy-unfriendly dtypes stored as raw bits + logical dtype name
_BITCAST = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}."))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}."))
    else:
        out[prefix[:-1]] = tree
    return out


def _subtree(flat, key):
    """Entries of `flat` under `key.` (or the exact `key` -> '')."""
    out = {}
    for kk, v in flat.items():
        if kk == key:
            out[""] = v
        elif kk.startswith(key + "."):
            out[kk[len(key) + 1:]] = v
    return out


def _unflatten_into(template, flat):
    if isinstance(template, dict):
        return {k: _unflatten_into(template[k], _subtree(flat, k))
                for k in template}
    if isinstance(template, (list, tuple)):
        typ = type(template)
        return typ(_unflatten_into(v, _subtree(flat, str(i)))
                   for i, v in enumerate(template))
    return flat[""]


def save(directory: str, step: int, state) -> str:
    """Atomically save a pytree `state` for `step`. Returns final path."""
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(directory, name + ".tmp")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    arrays_dir = os.path.join(tmp, "arrays")
    os.makedirs(arrays_dir)
    flat = _flatten(state)
    manifest = dict(step=step, leaves={})
    for key, val in flat.items():
        arr = np.asarray(jax.device_get(val))
        logical = str(arr.dtype)
        if logical in _BITCAST:           # np.save can't cast these
            arr = arr.view(_BITCAST[logical])
        np.save(os.path.join(arrays_dir, key + ".npy"), arr)
        manifest["leaves"][key] = dict(shape=list(arr.shape),
                                       dtype=logical)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(os.path.join(directory, "LATEST.tmp"), "w") as f:
        f.write(name)
        f.flush()
        os.fsync(f.fileno())
    os.replace(os.path.join(directory, "LATEST.tmp"),
               os.path.join(directory, "LATEST"))
    return final


def latest_step(directory: str) -> int | None:
    latest = os.path.join(directory, "LATEST")
    if not os.path.exists(latest):
        return None
    with open(latest) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(directory, name)):
        return None
    return int(name.split("_")[1])


def restore(directory: str, template, step: int | None = None):
    """Restore into the structure of `template` (shapes must match).

    With sharding rules installed, leaves are placed according to the
    template's shardings via `jax.device_put`.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat = {}
    for key, meta in manifest["leaves"].items():
        arr = np.load(os.path.join(path, "arrays", key + ".npy"))
        if meta["dtype"] in _BITCAST:
            arr = arr.view(getattr(ml_dtypes, meta["dtype"]))
        flat[key] = arr
    restored = _unflatten_into(template, flat)

    def place(t, v):
        arr = jax.numpy.asarray(v, dtype=t.dtype)
        if hasattr(t, "sharding") and t.sharding is not None:
            try:
                return jax.device_put(arr, t.sharding)
            except Exception:
                return arr
        return arr

    return jax.tree_util.tree_map(place, template, restored), step


def prune(directory: str, keep: int = 3):
    """Delete all but the newest `keep` complete checkpoints."""
    if not os.path.isdir(directory):
        return
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)
