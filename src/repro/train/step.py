"""Train-step builder: loss, grad accumulation, optimizer, shardings.

The built step is a single pjit-able function
``(params, opt_state, batch, rng) -> (params, opt_state, metrics)``
with

* next-token cross-entropy in fp32 over (possibly vocab-sharded)
  logits — the log-softmax reduction over a sharded vocab lowers to an
  all-reduce over the 'model' axis, never an all-gather of the logits,
* optional z-loss (stabilizes the softmax at scale),
* gradient accumulation over ``accum`` microbatches via `lax.scan` —
  peak activation memory is one microbatch; the scan also gives XLA a
  window to overlap the per-microbatch reduce-scatter of gradients
  with the next microbatch's compute,
* AdamW update (`repro.train.optimizer`).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.registry import ModelApi
from repro.parallel.axes import shard
from repro.train import optimizer as opt


def cross_entropy(logits, labels, *, z_loss: float = 0.0):
    """Mean next-token CE.  logits (B,S,V) (V may be sharded), fp32 math."""
    lg = logits.astype(jnp.float32)
    m = jnp.max(lg, axis=-1, keepdims=True)
    z = jnp.log(jnp.sum(jnp.exp(lg - m), axis=-1)) + m[..., 0]  # logZ
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    ce = jnp.mean(z - gold)
    if z_loss > 0.0:
        ce = ce + z_loss * jnp.mean(jnp.square(z))
    return ce


def build_loss_fn(api: ModelApi, *, z_loss: float = 0.0):
    def loss_fn(params, batch):
        logits = api.forward(params, batch)
        labels = batch["labels"]
        return cross_entropy(logits, labels, z_loss=z_loss)
    return loss_fn


def build_train_step(api: ModelApi, opt_cfg: opt.AdamWConfig, *,
                     accum: int = 1, z_loss: float = 0.0,
                     compress_grads=None):
    """Returns train_step(params, opt_state, batch) -> (p, s, metrics).

    batch leaves have a leading global-batch dim; with ``accum > 1``
    they are split into ``accum`` microbatches scanned sequentially.
    ``compress_grads`` is an optional fn applied to the accumulated
    gradient pytree (e.g. int8 compression with error feedback for the
    cross-pod reduction — `repro.parallel.compression`).
    """
    loss_fn = build_loss_fn(api, z_loss=z_loss)
    grad_fn = jax.value_and_grad(loss_fn)

    def train_step(params, opt_state, batch):
        if accum == 1:
            loss, grads = grad_fn(params, batch)
        else:
            def micro(mb):
                # Strided split: microbatch m = rows {i : i % accum == m}.
                # Each device's contiguous batch shard contributes equally
                # to every microbatch, so the split is collective-free
                # (a contiguous split would land each microbatch on
                # gb/accum/shard_size devices and force a reshard).
                return jax.tree_util.tree_map(
                    lambda x: jnp.moveaxis(
                        x.reshape(x.shape[0] // accum, accum,
                                  *x.shape[1:]), 1, 0), mb)

            def body(carry, mbatch):
                gsum, lsum = carry
                l, g = grad_fn(params, mbatch)
                gsum = jax.tree_util.tree_map(jnp.add, gsum, g)
                return (gsum, lsum + l), None

            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(
                body, (zero, jnp.zeros((), jnp.float32)), micro(batch))
            grads = jax.tree_util.tree_map(lambda g: g / accum, gsum)
            loss = lsum / accum

        if compress_grads is not None:
            grads = compress_grads(grads)
        params, opt_state, metrics = opt.apply_updates(
            opt_cfg, params, grads, opt_state)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    return train_step


def batch_specs(api: ModelApi):
    """Logical specs for the training batch dict."""
    spec = dict(tokens=("batch", None), labels=("batch", None))
    if api.needs_ctx:
        spec["ctx"] = ("batch", None, None)
    return spec
