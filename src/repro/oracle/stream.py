"""Command-stream extraction from ``cmd_trace=True`` runs.

`repro.core.platform.run_frontend` with ``StageConfig.cmd_trace=True``
emits the raw per-step `repro.core.dram.TickCmd` records as ``cmd_*``
views — dense in weave-scan steps, sparse in commands.  This module
flattens them into a `CommandStream`: one row per granted DRAM command
or refresh firing, time-ordered per channel, ready for the protocol
checker (`repro.oracle.checker`) and the ``.cmd.trace`` exporter
(`repro.obs.export.to_cmd_trace`).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.dram import ACT, NONE, PRE, RD, REF, WR
from repro.core.timing import DramParams

#: the raw per-step record series a ``cmd_trace=True`` views dict
#: carries (`repro.core.dram.TickCmd` fields, stacked ``(W, S, ...)``)
CMD_KEYS = ("cmd_cmd", "cmd_t", "cmd_fbank", "cmd_row",
            "cmd_ref", "cmd_ref_bank")

#: command-code -> mnemonic (the ``.cmd.trace`` vocabulary); REF splits
#: into REFab / REFsb at export time by the recorded bank
CMD_NAMES = {RD: "RD", WR: "WR", ACT: "ACT", PRE: "PRE", REF: "REF"}


@dataclasses.dataclass
class CommandStream:
    """A flattened DRAM command stream (host-side numpy, row-per-event).

    Rows are sorted by ``(channel, t)`` with a same-tick refresh
    ordered *before* a same-tick command grant — matching `dram.tick`,
    where the refresh deadline applies ahead of the FR-FCFS select.
    ``bank`` is the bank-in-rank index; a refresh row carries the
    refreshed bank (DDR5 REFsb) or ``-1`` for an all-bank refresh, and
    ``row`` is the ACT/CAS target row (``-1`` for PRE and REF).
    """

    dram: DramParams
    t: np.ndarray          # (N,) int64 absolute DRAM tick
    cmd: np.ndarray        # (N,) int32 RD/WR/ACT/PRE/REF
    channel: np.ndarray    # (N,) int32
    rank: np.ndarray       # (N,) int32
    bank: np.ndarray       # (N,) int32 bank-in-rank (-1: all-bank REF)
    row: np.ndarray        # (N,) int32 (-1 for PRE/REF)

    def __len__(self) -> int:
        return int(self.t.shape[0])

    def counts(self) -> dict:
        """Total command mix: ``{"RD": n, "WR": n, ...}``."""
        return {name: int(np.sum(self.cmd == code))
                for code, name in CMD_NAMES.items()}


def extract_stream(views, dram: DramParams) -> CommandStream:
    """Flatten one run's ``cmd_*`` views into a `CommandStream`.

    Args:
        views: the views dict of a single ``cmd_trace=True`` run of
            `repro.core.platform.run_frontend` (NOT a vmapped batch —
            index the batch axis down to one run first).
        dram: the run's device (``cfg.platform.dram``).

    Raises:
        ValueError: if the ``cmd_*`` keys are missing (the run was not
            recorded) or per-channel grant times are not strictly
            increasing (the views are not a single run's).
    """
    missing = [k for k in CMD_KEYS if k not in views]
    if missing:
        raise ValueError(
            f"views dict lacks command-record keys {missing}; rerun "
            "with StageConfig(cmd_trace=True) to record the stream")
    C = dram.n_channels
    R = dram.ranks_per_channel
    nbanks = dram.banks_per_rank
    cmd = np.asarray(views["cmd_cmd"]).reshape(-1, C)
    t = np.asarray(views["cmd_t"], np.int64).reshape(-1, C)
    fbank = np.asarray(views["cmd_fbank"]).reshape(-1, C)
    rowv = np.asarray(views["cmd_row"]).reshape(-1, C)
    ref = np.asarray(views["cmd_ref"]).reshape(-1, C, R)
    ref_bank = np.asarray(views["cmd_ref_bank"]).reshape(-1, C, R)

    # command grants: the steps where a channel issued something
    i, c = np.nonzero(cmd != NONE)
    parts = [(t[i, c], cmd[i, c], c, fbank[i, c] // nbanks,
              fbank[i, c] % nbanks, rowv[i, c])]
    # refresh firings: one row per (channel, rank) deadline hit
    i, c, r = np.nonzero(ref)
    parts.append((t[i, c], np.full(i.shape, REF), c, r,
                  ref_bank[i, c, r], np.full(i.shape, -1)))
    ts, cs, chs, rks, bks, rws = (
        np.concatenate([np.asarray(p[k]) for p in parts])
        for k in range(6))
    # channel-major, time-ordered; a refresh sorts before a same-tick
    # command grant (inside `tick` the deadline applies first), and the
    # rank index breaks the tie between two same-tick refreshes
    order = np.lexsort((rks, (cs != REF).astype(np.int8), ts, chs))
    out = CommandStream(
        dram=dram, t=ts[order].astype(np.int64),
        cmd=cs[order].astype(np.int32), channel=chs[order].astype(np.int32),
        rank=rks[order].astype(np.int32), bank=bks[order].astype(np.int32),
        row=rws[order].astype(np.int32))
    # single-run invariant: each evaluated tick grants at most one
    # command per channel, and no tick is evaluated twice
    for ch in range(C):
        tc = out.t[(out.channel == ch) & (out.cmd != REF)]
        if tc.size > 1 and not (np.diff(tc) > 0).all():
            raise ValueError(
                f"channel {ch} grant times are not strictly increasing"
                " — views are not a single run's cmd_trace record")
    return out


def stream_stats(stream: CommandStream, span_ticks: int | None = None):
    """Per-channel command mix (and bandwidth, given the tick span).

    Returns a dict with ``(C,)`` int arrays per mnemonic plus
    ``bytes``; ``span_ticks`` (total evaluated DRAM ticks) adds
    ``bw_gbs`` — the per-channel data bandwidth in GB/s, in the same
    unit convention as `repro.core.platform` (bytes/ps x 1e3).
    """
    d = stream.dram
    out = {}
    for code, name in CMD_NAMES.items():
        m = stream.cmd == code
        out[name] = np.bincount(stream.channel[m],
                                minlength=d.n_channels).astype(np.int64)
    out["bytes"] = (out["RD"] + out["WR"]) * d.line_bytes
    if span_ticks is not None:
        span_ps = float(span_ticks) * d.dram_ps_per_clk
        out["bw_gbs"] = out["bytes"] / max(span_ps, 1.0) * 1e3
    return out


def diff_streams(a: CommandStream, b: CommandStream):
    """First divergence between two streams, or ``None`` if identical.

    The differential harness's equality probe: returns a dict naming
    the first differing row (field values from both streams) or the
    length mismatch; ``None`` means the streams agree row-for-row.
    """
    fields = ("t", "cmd", "channel", "rank", "bank", "row")
    n = min(len(a), len(b))
    neq = np.zeros(n, bool)
    for f in fields:
        neq |= getattr(a, f)[:n] != getattr(b, f)[:n]
    at = lambda s, i: {f: int(getattr(s, f)[i]) for f in fields}
    if neq.any():
        i = int(np.flatnonzero(neq)[0])
        return dict(index=i, a=at(a, i), b=at(b, i),
                    n_a=len(a), n_b=len(b))
    if len(a) != len(b):
        i = n
        longer = a if len(a) > len(b) else b
        return dict(index=i, a=at(a, i) if len(a) > n else None,
                    b=at(b, i) if len(b) > n else None,
                    n_a=len(a), n_b=len(b))
    return None
