"""Vectorized DDRx protocol-legality checker for command streams.

Replays a recorded `CommandStream` against the device's `DramParams`
and asserts every timing window and bank state-machine rule the
controller model (`repro.core.dram.tick`) is supposed to respect —
from the stream alone, with no access to the simulator's internal
timers.  A clean report is machine-checked evidence that the granted
command sequence is protocol-legal; any violation is a bug in
`repro.core.dram`, never something to suppress here.

The rule set (`RULES`) mirrors the model's semantics exactly:

* bus turnaround is accounted on the *switching* burst (a rank switch
  extends that burst's bus occupancy by ``tRTRS``, delaying the next
  CAS), with rank 0 as the power-on "previous" rank;
* a refresh closes every covered bank (one bank for DDR5 REFsb, the
  whole rank otherwise) and blocks it for ``tRFC``;
* refresh deadlines are staggered per rank
  (``tREFI + r * (tREFI // R)``) and advance by exactly ``tREFI`` —
  window boundaries are contiguous in tick space, so a deadline fires
  at exactly its tick (``ref_slack`` loosens this for experiments).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.dram import ACT, PRE, RD, REF, WR
from repro.core.timing import DramParams
from repro.oracle.stream import CMD_NAMES, CommandStream

#: rule id -> human description (drives the docs/VALIDATION.md table)
RULES = {
    "state-act-closed": "ACT only targets a precharged bank "
                        "(no double-ACT)",
    "state-cas-open": "RD/WR only targets the bank's open row "
                      "(no CAS to a closed or mismatched row)",
    "state-pre-open": "PRE only targets an open bank",
    "trcd": "CAS >= same-bank ACT + tRCD",
    "tras": "PRE >= same-bank ACT + tRAS",
    "trp": "ACT >= same-bank PRE + tRP",
    "trc": "ACT >= same-bank ACT + tRC (= tRAS + tRP)",
    "trtp": "PRE >= same-bank RD + tRTP",
    "twr": "PRE >= same-bank WR + tCWL + tBL + tWR (write recovery)",
    "tccd-s": "CAS >= previous same-channel CAS + tCCD_S",
    "tccd-l": "CAS >= same-(rank, bank-group) CAS + tCCD_L",
    "bus": "CAS >= previous CAS + tBL (+ tRTRS when that burst "
           "switched ranks)",
    "twtr": "RD >= same-channel WR + tCWL + tBL + tWTR_L "
            "(write-to-read turnaround)",
    "trtw": "WR >= same-channel RD + tCL + tBL + tRTRS - tCWL "
            "(read-to-write turnaround)",
    "trrd-s": "ACT >= same-rank ACT + tRRD_S",
    "trrd-l": "ACT >= same-(rank, bank-group) ACT + tRRD_L",
    "tfaw": "ACT >= 4th-previous same-rank ACT + tFAW "
            "(rolling four-activate window)",
    "trfc": "ACT >= last refresh covering the bank + tRFC",
    "trefi": "k-th refresh of rank r fires at exactly "
             "tREFI + r * (tREFI // R) + k * tREFI (+ ref_slack)",
    "ref-missed": "every refresh deadline before end_tick has fired",
    "ref-rotation": "DDR5 REFsb walks banks round-robin from 0; "
                    "all-bank refresh records bank -1",
}

_NEG = -(1 << 40)          # "no predecessor" sentinel time
MAX_EXAMPLES = 20          # violation examples kept per rule


@dataclasses.dataclass
class LegalityReport:
    """Outcome of `check_stream`: per-rule check and violation counts.

    ``violations`` keeps at most `MAX_EXAMPLES` example rows per rule
    (``violation_counts`` always counts all of them); ``ok`` is True
    iff no rule fired anywhere.
    """

    n_commands: int
    counts: dict
    n_checked: dict
    violation_counts: dict
    violations: list

    @property
    def ok(self) -> bool:
        return not any(self.violation_counts.values())

    def summary(self) -> str:
        mix = " ".join(f"{k}={v}" for k, v in self.counts.items())
        n_bad = sum(self.violation_counts.values())
        head = (f"{self.n_commands} events ({mix}); "
                f"{sum(self.n_checked.values())} checks, "
                f"{n_bad} violations")
        if not n_bad:
            return head + " — protocol-legal"
        worst = [f"{r}:{n}" for r, n in self.violation_counts.items() if n]
        return head + " [" + " ".join(worst) + "]"

    def to_dict(self) -> dict:
        return dict(ok=self.ok, n_commands=self.n_commands,
                    counts=dict(self.counts),
                    n_checked=dict(self.n_checked),
                    violation_counts={k: v for k, v
                                      in self.violation_counts.items() if v},
                    violations=list(self.violations))


class _Acc:
    """Check/violation accumulator shared by the per-channel passes."""

    def __init__(self):
        self.n_checked = {r: 0 for r in RULES}
        self.violation_counts = {r: 0 for r in RULES}
        self.violations = []

    def check(self, rule, ch, bad, times, detail):
        """Record ``len(bad)`` comparisons, flagging the True ones."""
        bad = np.asarray(bad, bool)
        self.n_checked[rule] += int(bad.size)
        n_bad = int(bad.sum())
        if not n_bad:
            return
        self.violation_counts[rule] += n_bad
        room = MAX_EXAMPLES - min(
            sum(1 for v in self.violations if v["rule"] == rule),
            MAX_EXAMPLES)
        for i in np.flatnonzero(bad)[:room]:
            self.violations.append(dict(
                rule=rule, channel=int(ch), t=int(times[i]),
                detail=detail(int(i))))


def _last_idx(mask):
    """Exclusive index of the most recent True before each position."""
    if mask.size == 0:
        return np.zeros(0, np.int64)
    idx = np.where(mask, np.arange(mask.size), -1)
    return np.concatenate([[-1], np.maximum.accumulate(idx)[:-1]])


def _last_time(mask, t):
    """Exclusive most-recent time of a masked event (`_NEG` if none)."""
    li = _last_idx(mask)
    return np.where(li >= 0, t[np.maximum(li, 0)], _NEG)


def _window(acc, rule, ch, sel, t, ref_t, gap, name):
    """Flag ``t[sel] < ref_t[sel] + gap`` (a violated timing window)."""
    tv, rv = t[sel], ref_t[sel]
    bad = tv < rv + gap
    acc.check(rule, ch, bad, tv,
              lambda i: f"{name}: gap {int(tv[i] - rv[i])} < {int(gap)}")


def _check_bank(acc, d: DramParams, ch, t, k, row):
    """Per-bank pass: state machine + same-bank timing windows.

    ``t``/``k``/``row`` are one bank's event subsequence (time-ordered;
    ``k == REF`` rows are the refreshes covering this bank).
    """
    is_act, is_pre = k == ACT, k == PRE
    is_rd, is_wr = k == RD, k == WR
    is_close = is_pre | (k == REF)
    la, lc = _last_idx(is_act), _last_idx(is_close)
    is_open = la > lc
    open_row = np.where(is_open, row[np.maximum(la, 0)], -1)

    acc.check("state-act-closed", ch, is_open[is_act], t[is_act],
              lambda i: "ACT to an already-open bank")
    cas = is_rd | is_wr
    bad_cas = cas & (~is_open | (open_row != row))
    acc.check("state-cas-open", ch, bad_cas[cas], t[cas],
              lambda i, b=bad_cas, o=open_row, r=row, c=np.flatnonzero(cas):
              f"CAS row {int(r[c[i]])} vs open {int(o[c[i]])}")
    acc.check("state-pre-open", ch, ~is_open[is_pre], t[is_pre],
              lambda i: "PRE to a precharged bank")

    last_act_t = _last_time(is_act, t)
    _window(acc, "trcd", ch, cas, t, last_act_t, d.tRCD, "ACT->CAS")
    _window(acc, "tras", ch, is_pre, t, last_act_t, d.tRAS, "ACT->PRE")
    _window(acc, "trc", ch, is_act, t, last_act_t, d.tRC, "ACT->ACT")
    _window(acc, "trp", ch, is_act, t, _last_time(is_pre, t), d.tRP,
            "PRE->ACT")
    _window(acc, "trtp", ch, is_pre, t, _last_time(is_rd, t), d.tRTP,
            "RD->PRE")
    _window(acc, "twr", ch, is_pre, t, _last_time(is_wr, t),
            d.tCWL + d.tBL + d.tWR, "WR->PRE")
    _window(acc, "trfc", ch, is_act, t, _last_time(k == REF, t), d.tRFC,
            "REF->ACT")


def _check_channel_cas(acc, d: DramParams, ch, t, k, rank, grp):
    """Channel-wide CAS sequencing: tCCD, bus occupancy, turnarounds."""
    cas = (k == RD) | (k == WR)
    ct, cr = t[cas], rank[cas]
    if ct.size > 1:
        gap = np.diff(ct)
        acc.check("tccd-s", ch, gap < d.tCCD_S, ct[1:],
                  lambda i: f"CAS gap {int(gap[i])} < {d.tCCD_S}")
        # the bus charge of burst k includes tRTRS when *it* switched
        # ranks (power-on previous rank is 0, as in `init_banks`)
        prev = np.concatenate([[0], cr[:-1]])
        occ = d.tBL + np.where(cr != prev, d.tRTRS, 0)
        acc.check("bus", ch, gap < occ[:-1], ct[1:],
                  lambda i: f"CAS gap {int(gap[i])} < bus {int(occ[i])}")
    else:
        acc.check("tccd-s", ch, np.zeros(0, bool), ct, None)
        acc.check("bus", ch, np.zeros(0, bool), ct, None)
    # same-(rank, bank-group) CAS pairs: the long tCCD
    cg = (rank * d.bank_groups + grp)[cas]
    for g in np.unique(cg):
        gt = ct[cg == g]
        ggap = np.diff(gt)
        acc.check("tccd-l", ch, ggap < d.tCCD_L, gt[1:],
                  lambda i: f"same-group CAS gap {int(ggap[i])}"
                            f" < {d.tCCD_L}")
    # channel-wide write<->read turnarounds
    _window(acc, "twtr", ch, k == RD, t, _last_time(k == WR, t),
            d.tCWL + d.tBL + d.tWTR_L, "WR->RD")
    _window(acc, "trtw", ch, k == WR, t, _last_time(k == RD, t),
            d.tCL + d.tBL + d.tRTRS - d.tCWL, "RD->WR")


def _check_rank_act(acc, d: DramParams, ch, t, k, rank, grp):
    """Per-rank ACT pacing: tRRD_S/L and the tFAW sliding window."""
    act = k == ACT
    at, ar, ag = t[act], rank[act], grp[act]
    for r in range(d.ranks_per_channel):
        rt = at[ar == r]
        gap = np.diff(rt)
        acc.check("trrd-s", ch, gap < d.tRRD_S, rt[1:],
                  lambda i: f"rank {r} ACT gap {int(gap[i])}"
                            f" < {d.tRRD_S}")
        if rt.size > 4:
            fgap = rt[4:] - rt[:-4]
            acc.check("tfaw", ch, fgap < d.tFAW, rt[4:],
                      lambda i: f"rank {r} four-ACT span {int(fgap[i])}"
                                f" < {d.tFAW}")
    rg = ar * d.bank_groups + ag
    for g in np.unique(rg):
        gt = at[rg == g]
        ggap = np.diff(gt)
        acc.check("trrd-l", ch, ggap < d.tRRD_L, gt[1:],
                  lambda i: f"same-group ACT gap {int(ggap[i])}"
                            f" < {d.tRRD_L}")


def _check_refresh(acc, d: DramParams, ch, t, k, rank, bank,
                   end_tick, ref_slack):
    """Refresh cadence, coverage accounting, and REFsb rotation."""
    nbanks = d.banks_per_rank
    for r in range(d.ranks_per_channel):
        m = (k == REF) & (rank == r)
        rt, rb = t[m], bank[m]
        kk = np.arange(rt.size, dtype=np.int64)
        deadline = d.tREFI + r * (d.tREFI // d.ranks_per_channel)
        expect = deadline + kk * d.tREFI
        late = (rt < expect) | (rt > expect + ref_slack)
        acc.check("trefi", ch, late, rt,
                  lambda i: f"rank {r} REF #{int(kk[i])} at {int(rt[i])}"
                            f", deadline {int(expect[i])}"
                            + (f" (+{ref_slack})" if ref_slack else ""))
        if end_tick is not None:
            # integer ceil((end_tick - deadline) / tREFI), clamped at 0
            n_due = max(-((deadline - end_tick) // d.tREFI), 0)
            missed = rt.size < n_due
            acc.check("ref-missed", ch, np.asarray([missed]),
                      np.asarray([end_tick]),
                      lambda i: f"rank {r}: {rt.size} refreshes fired, "
                                f"{n_due} due before tick {end_tick}")
        if d.same_bank_refresh:
            bad = rb != (kk % nbanks)
            acc.check("ref-rotation", ch, bad, rt,
                      lambda i: f"rank {r} REFsb #{int(kk[i])} hit bank "
                                f"{int(rb[i])}, expected "
                                f"{int(kk[i] % nbanks)}")
        else:
            acc.check("ref-rotation", ch, rb != -1, rt,
                      lambda i: f"rank {r} all-bank REF recorded bank "
                                f"{int(rb[i])} (expected -1)")


def check_stream(stream: CommandStream, dram: DramParams | None = None,
                 *, end_tick: int | None = None,
                 ref_slack: int = 0) -> LegalityReport:
    """Check a recorded command stream for DDRx protocol legality.

    Args:
        stream: a `CommandStream` (`repro.oracle.extract_stream`).
        dram: device timings to check against; defaults to the
            stream's own `DramParams`.
        end_tick: total evaluated tick horizon of the run
            (``cfg.clock().window_end_tick(cfg.windows - 1)``); enables
            the missed-refresh rule.
        ref_slack: allowed lateness (ticks) past each refresh deadline;
            the default 0 asserts the model's exact-deadline firing.

    Returns:
        A `LegalityReport`; ``report.ok`` means every rule in `RULES`
        held everywhere.
    """
    d = dram or stream.dram
    nbanks = d.banks_per_rank
    acc = _Acc()
    for ch in range(d.n_channels):
        m = stream.channel == ch
        t = stream.t[m]
        k = stream.cmd[m]
        rank, bank, row = stream.rank[m], stream.bank[m], stream.row[m]
        grp = np.where(bank >= 0, bank, 0) // d.banks_per_group
        _check_channel_cas(acc, d, ch, t, k, rank, grp)
        _check_rank_act(acc, d, ch, t, k, rank, grp)
        _check_refresh(acc, d, ch, t, k, rank, bank, end_tick, ref_slack)
        # per-bank pass over an expanded view: an all-bank refresh
        # (bank == -1) becomes one close/block event per covered bank
        exp = k == REF if not d.same_bank_refresh else np.zeros_like(m[m])
        rep_n = np.where(exp, nbanks, 1).astype(np.int64)
        et = np.repeat(t, rep_n)
        ek = np.repeat(k, rep_n)
        erank = np.repeat(rank, rep_n)
        erow = np.repeat(row, rep_n)
        ebank = np.repeat(bank, rep_n)
        # walk each expanded refresh across its rank's banks
        pos = np.arange(et.size) - np.repeat(
            np.cumsum(rep_n) - rep_n, rep_n)
        ebank = np.where(np.repeat(exp, rep_n), pos, ebank)
        fb = erank * nbanks + ebank
        for f in np.unique(fb):
            bm = fb == f
            _check_bank(acc, d, ch, et[bm], ek[bm], erow[bm])
    counts = {name: int(np.sum(stream.cmd == code))
              for code, name in CMD_NAMES.items()}
    return LegalityReport(
        n_commands=len(stream), counts=counts,
        n_checked=acc.n_checked, violation_counts=acc.violation_counts,
        violations=acc.violations)
