"""Command-level differential oracle (`cmd_trace` consumer).

The third leg of the fidelity argument: the golden grid proves the two
weave engines are bit-identical to *each other*, the telemetry planes
expose what the controller did, and this package checks that what it
did is **DDRx-protocol legal** — every timing window and every bank
state-machine rule — from the recorded command stream alone, with no
access to the simulator's internal bookkeeping.

* `extract_stream` — flatten a ``cmd_trace=True`` run's raw ``cmd_*``
  views into a time-ordered per-channel `CommandStream`.
* `check_stream` — replay a stream against the device's `DramParams`
  and report every violation (`LegalityReport`, rules in `RULES`).
* `diff_streams` / `stream_stats` — engine-agreement helpers for the
  differential harness (`benchmarks/cmd_oracle.py`).

Export to the Ramulator2-compatible ``.cmd.trace`` text format lives
in `repro.obs.export` (`to_cmd_trace` / `validate_cmd_trace`).
"""
from repro.oracle.stream import (CommandStream, diff_streams,
                                 extract_stream, stream_stats)
from repro.oracle.checker import RULES, LegalityReport, check_stream

__all__ = [
    "CommandStream", "extract_stream", "stream_stats", "diff_streams",
    "RULES", "LegalityReport", "check_stream",
]
