"""Batched serving engine with continuous batching.

A fixed pool of ``n_slots`` decode slots runs one jitted decode step
per tick over the *whole* pool (static shapes — the TPU-friendly
formulation of continuous batching): finished or empty slots decode a
pad token and are masked out; new requests are admitted into free
slots between ticks by overwriting that slot's cache rows.

The decode step is the same `api.decode` lowered by the dry-run, so
the engine's cost model *is* the decode cell of the roofline table.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import ModelApi


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list          # token ids
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, api: ModelApi, params, *, n_slots: int = 4,
                 max_seq: int = 256, ctx=None, greedy: bool = True):
        self.api = api
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.cache = api.init_cache(n_slots, max_seq)
        if api.needs_ctx:
            assert ctx is not None, "modality ctx required"
            self.cache = api.fill_ctx(params, self.cache, ctx)
        self.slots: list[Request | None] = [None] * n_slots
        self.queue: list[Request] = []
        self.last_tok = np.zeros((n_slots,), np.int32)
        self._remaining_prompt: list[list] = [[] for _ in range(n_slots)]
        self.greedy = greedy
        self._step = jax.jit(api.decode)

    # -- admission ---------------------------------------------------------

    def submit(self, req: Request):
        self.queue.append(req)

    def _reset_slot(self, s: int):
        """Zero slot s's cache rows (length <- 0)."""
        def zero_row(x):
            if x.ndim >= 2 and x.shape[0] == self.n_slots:
                return x.at[s].set(0)
            if x.ndim >= 2 and x.shape[1] == self.n_slots:  # (L, B, ...)
                return x.at[:, s].set(0)
            return x
        self.cache = jax.tree_util.tree_map(zero_row, self.cache)
        self.cache["length"] = self.cache["length"].at[s].set(0)

    def _admit(self):
        for s in range(self.n_slots):
            if self.slots[s] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[s] = req
                self._reset_slot(s)
                self.last_tok[s] = req.prompt[0]
                self._remaining_prompt[s] = list(req.prompt[1:])

    # -- decode tick ---------------------------------------------------------

    def tick(self):
        """One decode step over the slot pool."""
        self._admit()
        toks = jnp.asarray(self.last_tok)
        logits, self.cache = self._step(self.params, self.cache, toks)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        for s, req in enumerate(self.slots):
            if req is None:
                continue
            if self._remaining_prompt[s]:
                # still force-feeding the prompt
                self.last_tok[s] = self._remaining_prompt[s].pop(0)
                continue
            req.out.append(int(nxt[s]))
            self.last_tok[s] = nxt[s]
            if len(req.out) >= req.max_new:
                req.done = True
                self.slots[s] = None

    def run(self, max_ticks: int = 1000) -> list[Request]:
        done = []
        pending = lambda: (self.queue
                           or any(r is not None for r in self.slots))
        ticks = 0
        submitted = []
        while pending() and ticks < max_ticks:
            before = [r for r in self.slots if r is not None]
            self.tick()
            ticks += 1
            for r in before:
                if r.done and r not in done:
                    done.append(r)
        return done
