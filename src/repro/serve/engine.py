"""Batched serving engine with continuous batching.

A fixed pool of ``n_slots`` decode slots runs one jitted decode step
per tick over the *whole* pool (static shapes — the TPU-friendly
formulation of continuous batching): finished or empty slots decode a
pad token and are masked out; new requests are admitted into free
slots between ticks by overwriting that slot's cache rows.

The decode step is the same `api.decode` lowered by the dry-run, so
the engine's cost model *is* the decode cell of the roofline table.

Slot admission itself — FIFO queue over a fixed slot pool — is
factored into `SlotPool` so the memory-traffic serving scheduler
(`repro.traces.llm.simulate_schedule`) drives the *same* admission
policy the model engine does: the traffic lowered onto the memory
platform follows the exact slot-recycling behaviour of this engine.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import ModelApi


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list          # token ids
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class SlotPool:
    """FIFO admission over a fixed pool of continuous-batching slots.

    Holds arbitrary request objects: a ``None`` slot is free, anything
    else is an in-flight request.  `admit` fills free slots from the
    queue in submission order and reports the ``(slot, request)``
    pairs it placed, so callers (the model `Engine`, the serving
    scheduler in `repro.traces.llm`) can run their per-admission setup
    (cache reset, arrival bookkeeping) against one shared policy.
    """

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.n_slots = n_slots
        self.slots: list = [None] * n_slots
        self.queue: list = []

    def submit(self, req) -> None:
        self.queue.append(req)

    def admit(self) -> list:
        """Fill free slots FIFO; returns the new ``(slot, req)`` pairs."""
        placed = []
        for s in range(self.n_slots):
            if self.slots[s] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[s] = req
                placed.append((s, req))
        return placed

    def free(self, s: int) -> None:
        self.slots[s] = None

    def active(self) -> list:
        """In-flight ``(slot, req)`` pairs, slot order."""
        return [(s, r) for s, r in enumerate(self.slots) if r is not None]

    def pending(self) -> bool:
        """True while anything is queued or in flight."""
        return bool(self.queue) or any(r is not None for r in self.slots)


class Engine:
    def __init__(self, api: ModelApi, params, *, n_slots: int = 4,
                 max_seq: int = 256, ctx=None, greedy: bool = True):
        self.api = api
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.cache = api.init_cache(n_slots, max_seq)
        if api.needs_ctx:
            assert ctx is not None, "modality ctx required"
            self.cache = api.fill_ctx(params, self.cache, ctx)
        self.pool = SlotPool(n_slots)
        self.last_tok = np.zeros((n_slots,), np.int32)
        self._remaining_prompt: list[list] = [[] for _ in range(n_slots)]
        self.greedy = greedy
        self._step = jax.jit(api.decode)

    # the pool's lists are the live state; expose them under the
    # historical attribute names (mutating e.g. ``eng.slots[0]`` is
    # mutating the pool)
    @property
    def slots(self) -> list:
        return self.pool.slots

    @property
    def queue(self) -> list:
        return self.pool.queue

    # -- admission ---------------------------------------------------------

    def submit(self, req: Request):
        if not req.prompt:
            raise ValueError(
                f"request {req.rid}: empty prompt (admission would have "
                "no token to feed)")
        if req.max_new < 1:
            raise ValueError(
                f"request {req.rid}: max_new must be >= 1, got "
                f"{req.max_new}")
        self.pool.submit(req)

    def _reset_slot(self, s: int):
        """Zero slot s's cache rows (length <- 0)."""
        def zero_row(x):
            if x.ndim >= 2 and x.shape[0] == self.n_slots:
                return x.at[s].set(0)
            if x.ndim >= 2 and x.shape[1] == self.n_slots:  # (L, B, ...)
                return x.at[:, s].set(0)
            return x
        self.cache = jax.tree_util.tree_map(zero_row, self.cache)
        self.cache["length"] = self.cache["length"].at[s].set(0)

    def _admit(self):
        for s, req in self.pool.admit():
            self._reset_slot(s)
            self.last_tok[s] = req.prompt[0]
            self._remaining_prompt[s] = list(req.prompt[1:])

    # -- decode tick ---------------------------------------------------------

    def tick(self) -> list[Request]:
        """One decode step over the slot pool; returns requests that
        completed on this tick (admission included — a one-token
        prompt with ``max_new=1`` completes on its admission tick)."""
        self._admit()
        toks = jnp.asarray(self.last_tok)
        logits, self.cache = self._step(self.params, self.cache, toks)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        completed = []
        for s, req in enumerate(self.pool.slots):
            if req is None:
                continue
            if self._remaining_prompt[s]:
                # still force-feeding the prompt
                self.last_tok[s] = self._remaining_prompt[s].pop(0)
                continue
            req.out.append(int(nxt[s]))
            self.last_tok[s] = nxt[s]
            if len(req.out) >= req.max_new:
                req.done = True
                self.pool.free(s)
                completed.append(req)
        return completed

    def run(self, max_ticks: int = 1000) -> list[Request]:
        """Tick until drained or ``max_ticks``; returns finished requests.

        Hitting ``max_ticks`` is not an error: in-flight requests keep
        their partial ``out`` and queued requests stay queued, so a
        subsequent `run` (or `tick`) call resumes exactly where this
        one stopped.
        """
        done = []
        ticks = 0
        while self.pool.pending() and ticks < max_ticks:
            done.extend(self.tick())
            ticks += 1
        return done
