"""Logical-axis sharding system.

Models annotate arrays with *logical* axis names; the launcher maps
those names onto physical mesh axes.  This keeps every model definition
mesh-agnostic: the same code lowers on 1 CPU device (all rules empty),
a 16x16 single pod, or the (2, 16, 16) multi-pod production mesh —
pod-count scaling is a rules change, not a code change.

Logical axes used across the framework:

* ``batch``    — data-parallel batch dim -> ('pod', 'data')
* ``fsdp``     — parameter / optimizer-state sharding (ZeRO-3) -> 'data'
  (+ 'pod' for giant archs; see rules presets)
* ``heads``    — attention-head tensor parallelism -> 'model'
* ``kv_heads`` — GQA KV heads -> 'model' *only if divisible*
* ``mlp``      — FFN hidden dim -> 'model'
* ``vocab``    — embedding / logits vocab dim -> 'model'
* ``experts``  — MoE expert dim -> 'model' if divisible (EP), else the
  per-expert ``mlp`` dim carries the TP (grok-style 8e on 16-way TP)
* ``seq``      — sequence-parallel activations / sharded KV cache
* ``state``    — SSM value-dim tensor parallelism (xLSTM / Mamba2)

Divisibility fallback: `resolve()` drops a mesh axis whose size does
not divide the array dim (replicating instead of uneven sharding), so
e.g. whisper's 20 heads simply replicate on a 16-way 'model' axis while
its 5120 FFN still shards.  The decision is static (shapes are static)
and logged once per unique (name, dim) by the dry-run.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _rules() -> Mapping[str, tuple[str, ...]]:
    return getattr(_state, "rules", {})


def _mesh() -> Mesh | None:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def sharding_rules(mesh: Mesh | None, rules: Mapping[str, Sequence[str]]):
    """Install logical->physical axis rules for the enclosed scope."""
    prev = (_mesh(), _rules())
    _state.mesh = mesh
    _state.rules = {k: tuple(v) if not isinstance(v, str) else (v,)
                    for k, v in rules.items()}
    try:
        yield
    finally:
        _state.mesh, _state.rules = prev


#: rules presets -----------------------------------------------------------

def single_pod_rules() -> dict:
    # kv_seq lists both axes: under the first-dim-wins dedup in
    # resolve(), a batch-sharded decode cache gets seq over 'model'
    # (flash-decoding split-KV), while the batch=1 long-context cell
    # gets seq over BOTH axes (256-way KV sharding).
    return dict(batch=("data",), fsdp=("data",), embed=("data",),
                heads=("model",), kv_heads=("model",), mlp=("model",),
                vocab=("model",), experts=("model",), seq=("model",),
                state=("model",), kv_seq=("data", "model"))


def multi_pod_rules() -> dict:
    r = single_pod_rules()
    r["batch"] = ("pod", "data")
    r["fsdp"] = ("pod", "data")
    r["embed"] = ("pod", "data")
    r["kv_seq"] = ("pod", "data", "model")
    return r


def serve_rules(multi_pod: bool = False) -> dict:
    """Weight-stationary serving layout (§Perf iteration 2).

    Training shards parameters over 'data' (ZeRO/FSDP) and re-gathers
    them per layer — amortized over a big batch that is fine, but at
    decode it moves the ENTIRE model across the mesh every step
    (measured: 1.78 s collective term for arctic-480b/decode_32k,
    ~58 GB of expert weights per step).  For serving, parameters are
    instead sharded over BOTH mesh axes and never gathered: 'fsdp' is
    dropped and the FFN/expert-hidden dim picks up the 'data' axis.
    Activations (tiny at decode) move instead of weights.
    """
    r = single_pod_rules()
    r["fsdp"] = ()
    r["embed"] = ("data",)     # weights stay resident, 256-way with TP
    r["mlp"] = ("data", "model")
    r["state"] = ("data", "model")
    r["__serving__"] = ()          # mode marker, see serving_mode()
    if multi_pod:
        r["batch"] = ("pod", "data")
        r["kv_seq"] = ("pod", "data", "model")
        r["mlp"] = ("pod", "data", "model")
    return r


def serving_mode() -> bool:
    """True when the installed rules are the serving preset."""
    return "__serving__" in _rules()


def _axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def resolve(names: Sequence[str | None],
            shape: Sequence[int] | None = None) -> P:
    """Logical axis names -> PartitionSpec under the installed rules.

    With ``shape`` given, any mesh axis whose size does not divide the
    corresponding dim is dropped (replication fallback).
    """
    rules, mesh = _rules(), _mesh()
    if not rules:
        return P()
    sizes = _axis_sizes(mesh) if mesh is not None else {}
    out, used = [], set()
    for i, name in enumerate(names):
        if name is None:
            out.append(None)
            continue
        axes = tuple(ax for ax in rules.get(name, ()) if ax not in used)
        if shape is not None and sizes:
            keep, dim = [], shape[i]
            for ax in axes:
                sz = sizes.get(ax, 1)
                if sz > 1 and dim % sz == 0:
                    keep.append(ax)
                    dim //= sz
            axes = tuple(keep)
        used.update(axes)
        out.append(axes if len(axes) > 1 else (axes[0] if axes else None))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def shard(x, *names: str | None):
    """`with_sharding_constraint` by logical names (no-op w/o rules)."""
    if not _rules() or _mesh() is None:
        return x
    spec = resolve(names, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_mesh(), spec))


def named_sharding(spec: P) -> NamedSharding:
    mesh = _mesh()
    assert mesh is not None, "no mesh installed"
    return NamedSharding(mesh, spec)


def spec_tree_to_shardings(spec_tree, shape_tree):
    """Map a pytree of logical-name tuples to NamedShardings.

    spec_tree leaves: tuple of logical names (or None) per array dim.
    shape_tree leaves: arrays or ShapeDtypeStructs (for divisibility).
    """
    mesh = _mesh()
    assert mesh is not None

    def one(names, arr):
        return NamedSharding(mesh, resolve(names, arr.shape))

    return jax.tree_util.tree_map(
        one, spec_tree, shape_tree,
        is_leaf=lambda x: (isinstance(x, tuple)
                           and all(isinstance(n, (str, type(None)))
                                   for n in x)))
