"""Int8 gradient compression with error feedback.

At multi-pod scale the cross-pod gradient reduction rides the slowest
links; compressing gradients to int8 (per-tensor scale) cuts those
bytes 4x.  Error feedback (Seide et al.; 1-bit SGD lineage) keeps the
quantization *unbiased over time*: the residual of each step's
quantization is added back before the next step's quantization, so the
series of applied updates converges to the uncompressed series.

Usage (trainer wires this in when ``--compress-grads`` is set)::

    state = init_error_feedback(params)
    def hook(grads):
        nonlocal state
        grads, state = compress_decompress(grads, state)
        return grads

In the pjit train step the quantize -> (cross-pod reduce) -> dequantize
round-trip is expressed as quantize/dequantize around the gradient
pytree; XLA places the cross-pod all-reduce between them because the
dequantized values are what the (pod-replicated) optimizer consumes.
The compression itself is exact-shape, jit-able, differentiable-free
dataflow.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_feedback(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize_int8(x):
    """Per-tensor symmetric int8. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_decompress(grads, ef_state):
    """Quantize+dequantize every gradient leaf with error feedback.

    Returns (decompressed_grads, new_ef_state).
    """
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = quantize_int8(g32)
        deq = dequantize_int8(q, s)
        return deq, g32 - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
