"""Three-perspective observability (`repro.obs`).

The paper's thesis is that the *simulator*, *CPU-memory interface*,
and *application* perspectives of the same run can diverge — and that
the correction ladder (stages 01→10) re-couples them.  This package
turns the platform's in-kernel telemetry planes (enabled with
``StageConfig(telemetry=True)``) into inspectable artifacts:

* `repro.obs.telemetry` — collect the raw ``tele_*`` view series into
  a `TelemetryRecord`; reduce to command mixes, row-locality splits,
  bank utilization, and latency percentiles.
* `repro.obs.export` — structured JSON reports and a Chrome-trace /
  Perfetto JSON timeline (per-channel command tracks, write-drain
  phase slices, per-core progress tracks), plus the Ramulator2-
  compatible ``.cmd.trace`` exporter for recorded `repro.oracle`
  command streams.
* `repro.obs.perspectives` — per-window rank correlation between the
  three views' latency/progress series: the machine-readable
  "perspectives diverge, corrections re-couple them" report.

Telemetry is a **static** `StageConfig` flag: when off (default) the
traced computation is exactly the historical graph — bit-identical
outputs, zero cost.  When on, every counter is *event-accounted*
inside `repro.core.dram.tick`, so both weave engines (dense and
event-horizon) produce identical planes.
"""
from repro.obs.telemetry import (TELE_KEYS, TelemetryRecord, collect,
                                 hist_edges, hist_percentiles, summarize)
from repro.obs.export import (to_cmd_trace, to_json, to_perfetto,
                              validate_cmd_trace, validate_perfetto)
from repro.obs.perspectives import divergence_report, spearman, window_series

__all__ = [
    "TELE_KEYS", "TelemetryRecord", "collect", "hist_edges",
    "hist_percentiles", "summarize", "to_json", "to_perfetto",
    "validate_perfetto", "to_cmd_trace", "validate_cmd_trace",
    "divergence_report", "spearman", "window_series",
]
