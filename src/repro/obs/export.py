"""Exporters: structured JSON and Chrome-trace / Perfetto timelines.

`to_json` flattens a `TelemetryRecord` plus its `summarize` reduction
into one JSON-serializable report.  `to_perfetto` renders the record
as a Chrome trace-event timeline (the JSON array format both
``chrome://tracing`` and https://ui.perfetto.dev open directly):

* **pid 1 "memory"** — one thread per channel.  Per window, a counter
  event with the command mix (``act``/``pre``/``cas_rd``/``cas_wr``/
  ``ref``) and queue depth; write-drain phases render as complete
  slices (``X`` events) with their accounted dwell as duration.
* **pid 2 "cores"** — one thread per core with a per-window progress
  counter (the application view), when the record carries a replay
  ``progress`` history.
* **pid 3 "interface"** — MSHR budget and the PI latency estimate.

Timestamps are window starts on the CPU clock
(`ClockModel.window_cpu_ps`-style: ``w * window_cycles *
cpu_ps_per_clk``), converted to the format's microseconds.

`validate_perfetto` is the schema check CI runs on exported traces.

`to_cmd_trace` / `validate_cmd_trace` export and schema-check the
command-level view: a recorded `repro.oracle.CommandStream` rendered
as the Ramulator2-compatible ``.cmd.trace`` text format (one granted
DRAM command or refresh per line), for differential replay against an
external simulator.
"""
from __future__ import annotations

import json

import numpy as np

from repro.obs.telemetry import TelemetryRecord, summarize

#: trace-event process ids (one per perspective)
PID_MEMORY, PID_CORES, PID_INTERFACE = 1, 2, 3


def to_json(rec: TelemetryRecord, path=None) -> dict:
    """Structured JSON report: summary + full per-window series.

    Args:
        rec: a collected `TelemetryRecord`.
        path: optional file to write (indent-2 JSON, trailing newline).
    Returns:
        The report dict (JSON-serializable).
    """
    report = dict(
        schema="repro.obs/telemetry-v1",
        stage=rec.stage, windows=rec.windows, warmup=rec.warmup,
        n_channels=rec.n_channels, window_ps=rec.window_ps(),
        dram_ps_per_clk=rec.dram_ps_per_clk,
        summary=summarize(rec),
        series={k: np.asarray(v).tolist() for k, v in rec.series.items()},
    )
    if rec.app_lat_cycles is not None:
        report["app_lat_cycles"] = np.asarray(rec.app_lat_cycles).tolist()
    if path is not None:
        with open(path, "w") as f:
            json.dump(report, f, indent=2)
            f.write("\n")
    return report


def _meta(pid, name, tid=None, tname=None):
    ev = [dict(ph="M", pid=pid, name="process_name",
               args=dict(name=name))]
    if tid is not None:
        ev.append(dict(ph="M", pid=pid, tid=tid, name="thread_name",
                       args=dict(name=tname)))
    return ev


def to_perfetto(rec: TelemetryRecord, path=None, max_cores: int = 8):
    """Render a record as a Chrome trace-event / Perfetto timeline.

    Args:
        rec: a collected `TelemetryRecord`.
        path: optional file to write the JSON trace to.
        max_cores: cap on per-core progress tracks (mixes run 24+
            cores; the first ``max_cores`` keep the timeline legible).
    Returns:
        The trace dict: ``{"traceEvents": [...], "displayTimeUnit":
        "ms"}``.
    """
    s = rec.series
    W, C = rec.windows, rec.n_channels
    wps = rec.window_ps()
    us = lambda w: w * wps / 1e6            # window start, microseconds
    events = _meta(PID_MEMORY, "memory")[:1]
    for c in range(C):
        events += _meta(PID_MEMORY, "memory", c, f"channel {c}")[1:]
        for w in range(W):
            events.append(dict(
                ph="C", pid=PID_MEMORY, tid=c, ts=us(w),
                name=f"ch{c} commands",
                args=dict(act=int(s["tele_n_act"][w, c]),
                          pre=int(s["tele_n_pre"][w, c]),
                          cas_rd=int(s["tele_n_cas_rd"][w, c]),
                          cas_wr=int(s["tele_n_cas_wr"][w, c]),
                          ref=int(s["tele_n_ref"][w, c]))))
            events.append(dict(
                ph="C", pid=PID_MEMORY, tid=c, ts=us(w),
                name=f"ch{c} queue depth",
                args=dict(depth=int(s["tele_queue_depth"][w, c]))))
            # drain service dwell (accrued at write-CAS grants):
            # render one slice per window with nonzero dwell, ending
            # at the window boundary
            dt = int(s["tele_drain_ticks"][w, c])
            if dt > 0:
                dur = dt * rec.dram_ps_per_clk / 1e6
                events.append(dict(
                    ph="X", pid=PID_MEMORY, tid=c,
                    ts=max(us(w + 1) - dur, 0.0), dur=dur,
                    name="write drain",
                    args=dict(entries=int(s["tele_drain_enter"][w, c]))))
    events += _meta(PID_INTERFACE, "interface", 0, "mshr / latency")[0:]
    for w in range(W):
        events.append(dict(
            ph="C", pid=PID_INTERFACE, tid=0, ts=us(w), name="interface",
            args=dict(mshr_budget=int(s["tele_mshr_budget"][w]),
                      lat_est_ns=float(s["tele_lat_est_ps"][w]) * 1e-3)))
    if rec.progress is not None:
        prog = np.asarray(rec.progress)
        events += _meta(PID_CORES, "cores")[:1]
        for core in range(min(prog.shape[-1], max_cores)):
            events += _meta(PID_CORES, "cores", core, f"core {core}")[1:]
            for w in range(W):
                events.append(dict(
                    ph="C", pid=PID_CORES, tid=core, ts=us(w),
                    name=f"core {core} progress",
                    args=dict(pos=int(prog[w, core]))))
    trace = dict(traceEvents=events, displayTimeUnit="ms")
    if path is not None:
        with open(path, "w") as f:
            json.dump(trace, f)
            f.write("\n")
    return trace


def validate_perfetto(obj) -> int:
    """Schema-check a Chrome trace-event object; the CI gate.

    Verifies the trace is loadable by Perfetto / chrome://tracing:
    a ``traceEvents`` list whose entries carry a valid ``ph`` with the
    fields that phase requires (counters need ``ts`` + numeric
    ``args``; complete slices need ``ts`` + ``dur``), and that at
    least one per-channel command counter track exists.

    Returns the number of events checked; raises `ValueError` on any
    violation.
    """
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("not a trace object: missing 'traceEvents'")
    events = obj["traceEvents"]
    if not isinstance(events, list) or not events:
        raise ValueError("'traceEvents' must be a non-empty list")
    n_cmd_tracks = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i}: not an object")
        ph = ev.get("ph")
        if ph not in ("M", "C", "X", "B", "E", "i"):
            raise ValueError(f"event {i}: unsupported phase {ph!r}")
        if "pid" not in ev or "name" not in ev:
            raise ValueError(f"event {i}: missing pid/name")
        if ph in ("C", "X"):
            if not isinstance(ev.get("ts"), (int, float)):
                raise ValueError(f"event {i}: {ph!r} needs numeric ts")
        if ph == "C":
            args = ev.get("args")
            if (not isinstance(args, dict) or not args or
                    not all(isinstance(v, (int, float))
                            for v in args.values())):
                raise ValueError(f"event {i}: counter args must be a "
                                 "non-empty numeric dict")
            if "commands" in ev["name"]:
                n_cmd_tracks += 1
        if ph == "X" and not isinstance(ev.get("dur"), (int, float)):
            raise ValueError(f"event {i}: 'X' slice needs numeric dur")
    if n_cmd_tracks == 0:
        raise ValueError("no per-channel command counter tracks found")
    return len(events)


#: the ``.cmd.trace`` format marker (line 1) and row header (line 3)
CMD_TRACE_HEADER = "# repro.oracle cmd-trace-v1"
CMD_TRACE_COLUMNS = "# tick,channel,cmd,rank,bank_group,bank,row"
#: command vocabulary: refresh splits by coverage (all-bank / same-bank)
CMD_TRACE_CMDS = ("ACT", "PRE", "RD", "WR", "REFab", "REFsb")


def to_cmd_trace(stream, path=None, preset: str = "") -> str:
    """Render a `repro.oracle.CommandStream` as ``.cmd.trace`` text.

    The format (documented in docs/VALIDATION.md, checked by
    `validate_cmd_trace`): a version marker, a geometry metadata
    comment, a column header, then one CSV row per granted command or
    refresh — the Ramulator2 command vocabulary (``ACT``/``PRE``/
    ``RD``/``WR``/``REFab``/``REFsb``) with absolute DRAM-tick
    timestamps, ready for replay against an external simulator.  Rows
    are channel-major and time-ordered per channel (a refresh precedes
    a same-tick grant); ``-1`` marks fields a command does not carry
    (``row`` for PRE/REF, ``bank_group``/``bank`` for REFab).

    Args:
        stream: the recorded `repro.oracle.CommandStream`.
        path: optional file to write the text to.
        preset: device-preset name for the metadata line.
    Returns:
        The full trace text (newline-terminated).
    """
    from repro.core.dram import ACT, PRE, RD, REF, WR
    d = stream.dram
    bpg = d.banks_per_group
    lines = [
        CMD_TRACE_HEADER,
        (f"# preset={preset or 'custom'} channels={d.n_channels}"
         f" ranks={d.ranks_per_channel} banks={d.banks_per_rank}"
         f" bank_groups={d.bank_groups} tck_ps={d.dram_ps_per_clk}"),
        CMD_TRACE_COLUMNS,
    ]
    names = {ACT: "ACT", PRE: "PRE", RD: "RD", WR: "WR"}
    for i in range(len(stream)):
        cmd, bank = int(stream.cmd[i]), int(stream.bank[i])
        if cmd == REF:
            name = "REFsb" if bank >= 0 else "REFab"
        else:
            name = names[cmd]
        grp = bank // bpg if bank >= 0 else -1
        lines.append(f"{int(stream.t[i])},{int(stream.channel[i])},"
                     f"{name},{int(stream.rank[i])},{grp},{bank},"
                     f"{int(stream.row[i])}")
    text = "\n".join(lines) + "\n"
    if path is not None:
        with open(path, "w") as f:
            f.write(text)
    return text


def validate_cmd_trace(text: str) -> int:
    """Schema-check ``.cmd.trace`` text; the CI gate for exports.

    Verifies the version marker, the geometry metadata, the column
    header, and every row: known command mnemonic, fields in range for
    the declared geometry, ``-1`` conventions respected (REFab carries
    no group/bank/row, PRE no row, ACT/RD/WR a real row), and grant
    times strictly increasing per channel (refreshes may share the
    tick of a grant, never regress).

    Returns the number of command rows; raises `ValueError` on any
    violation.
    """
    lines = text.splitlines()
    if len(lines) < 4:
        raise ValueError("truncated trace: header + at least one row "
                         "required")
    if lines[0] != CMD_TRACE_HEADER:
        raise ValueError(f"line 1: expected {CMD_TRACE_HEADER!r}")
    if not lines[1].startswith("# "):
        raise ValueError("line 2: missing metadata comment")
    meta = {}
    for tok in lines[1][2:].split():
        if "=" not in tok:
            raise ValueError(f"line 2: malformed metadata token {tok!r}")
        key, _, val = tok.partition("=")
        meta[key] = val
    geom = {}
    for key in ("channels", "ranks", "banks", "bank_groups", "tck_ps"):
        if key not in meta:
            raise ValueError(f"line 2: metadata lacks {key!r}")
        try:
            geom[key] = int(meta[key])
        except ValueError:
            raise ValueError(f"line 2: {key} must be an int, "
                             f"got {meta[key]!r}") from None
    if lines[2] != CMD_TRACE_COLUMNS:
        raise ValueError(f"line 3: expected {CMD_TRACE_COLUMNS!r}")
    bpg = geom["banks"] // geom["bank_groups"]
    last_t = {}
    n = 0
    for ln, line in enumerate(lines[3:], start=4):
        fields = line.split(",")
        if len(fields) != 7:
            raise ValueError(f"line {ln}: expected 7 fields, "
                             f"got {len(fields)}")
        cmd = fields[2]
        if cmd not in CMD_TRACE_CMDS:
            raise ValueError(f"line {ln}: unknown command {cmd!r}")
        try:
            t, ch, rank, grp, bank, row = (
                int(fields[i]) for i in (0, 1, 3, 4, 5, 6))
        except ValueError:
            raise ValueError(
                f"line {ln}: non-integer field in {line!r}") from None
        if not 0 <= ch < geom["channels"]:
            raise ValueError(f"line {ln}: channel {ch} out of range")
        if not 0 <= rank < geom["ranks"]:
            raise ValueError(f"line {ln}: rank {rank} out of range")
        if cmd == "REFab":
            if (grp, bank, row) != (-1, -1, -1):
                raise ValueError(f"line {ln}: REFab must carry "
                                 "group/bank/row = -1")
        else:
            if not 0 <= bank < geom["banks"]:
                raise ValueError(f"line {ln}: bank {bank} out of range")
            if grp != bank // bpg:
                raise ValueError(f"line {ln}: bank_group {grp} "
                                 f"inconsistent with bank {bank}")
            if cmd in ("ACT", "RD", "WR") and row < 0:
                raise ValueError(f"line {ln}: {cmd} needs a row >= 0")
            if cmd in ("PRE", "REFsb") and row != -1:
                raise ValueError(f"line {ln}: {cmd} must carry row -1")
        # per-channel ordering: grants strictly increase; a refresh may
        # share a grant's tick but then must precede it (refresh
        # applies first inside a tick), and refresh ticks never regress
        lc, lr = last_t.get(ch, (-1, -1))
        if t <= lc or t < lr:
            raise ValueError(f"line {ln}: channel {ch} tick {t} not "
                             f"after previous grant {lc} / refresh {lr}")
        last_t[ch] = (lc, t) if cmd.startswith("REF") else (t, lr)
        n += 1
    if n == 0:
        raise ValueError("trace carries no command rows")
    return n
