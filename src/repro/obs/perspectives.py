"""Per-window divergence between the three perspectives.

The paper's narrative, made machine-readable: for one run, build the
per-window latency series each perspective reports —

* **simulator view** — mean read latency out of the DRAM histograms
  (DRAM ticks x 750 ps);
* **interface view** — mean CPU-perceived read latency (the
  ``tele_hist_if_ps`` histogram);
* **application view** — the bound-phase load-to-use latency
  (``WindowOut.app_lat_cycles``) and the per-window progress *rate*
  (application throughput);

— and rank-correlate them window by window (`spearman`).  In the
broken stages the application series is *constant* (the DAMOV
immediate-response latency never moves, whatever the memory system
does), so its correlation with the simulator view is ~0: the
perspectives have decoupled.  The stage-04 PI controller feeds the
weave-phase latency back into the bound phase, and the correlation
jumps toward 1 — `divergence_report` tabulates that re-coupling
across the correction ladder.
"""
from __future__ import annotations

import numpy as np

from repro.core.dram import N_HIST


def _ranks(x: np.ndarray) -> np.ndarray:
    """Average ranks (ties share their mean rank), 1-based."""
    x = np.asarray(x, np.float64)
    order = np.argsort(x, kind="stable")
    ranks = np.empty_like(x)
    ranks[order] = np.arange(1, len(x) + 1, dtype=np.float64)
    # average the ranks inside each tie group
    sx = x[order]
    i = 0
    while i < len(sx):
        j = i
        while j + 1 < len(sx) and sx[j + 1] == sx[i]:
            j += 1
        if j > i:
            ranks[order[i:j + 1]] = ranks[order[i:j + 1]].mean()
        i = j + 1
    return ranks


def spearman(a, b) -> float:
    """Spearman rank correlation with average-rank tie handling.

    A zero-variance series (every value identical — the decoupled
    application view in the broken stages) correlates with nothing:
    returns 0.0 rather than nan, which is exactly the "application
    perspective carries no information about the memory system"
    reading the report wants.
    """
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    if a.shape != b.shape or a.ndim != 1:
        raise ValueError(f"series shapes differ: {a.shape} vs {b.shape}")
    ra, rb = _ranks(a), _ranks(b)
    sa, sb = ra.std(), rb.std()
    if sa == 0.0 or sb == 0.0:
        return 0.0
    return float(np.mean((ra - ra.mean()) * (rb - rb.mean())) / (sa * sb))


def window_series(rec) -> dict:
    """Post-warmup per-window series of the three perspectives.

    Args:
        rec: a `TelemetryRecord` collected with ``outs`` (the
            application view needs ``app_lat_cycles``; ``app_rate``
            additionally needs a replay ``progress`` history and is
            omitted for Mess-style synthetic frontends).
    Returns:
        dict of aligned ``(W - warmup,)`` float arrays:
        ``sim_lat_ns`` / ``if_lat_ns`` / ``app_lat_ns`` (+
        ``app_rate`` when available: summed per-window progress
        increments, accesses/window).
    """
    s, w0 = rec.series, rec.warmup
    centers = 1.5 * (2.0 ** np.arange(N_HIST))     # bucket midpoints
    h_rd = np.asarray(s["tele_hist_rd_ticks"][w0:], np.float64).sum(axis=1)
    h_if = np.asarray(s["tele_hist_if_ps"][w0:], np.float64).sum(axis=1)
    n = np.maximum(h_rd.sum(axis=-1), 1.0)
    out = dict(
        sim_lat_ns=(h_rd @ centers) / n * rec.dram_ps_per_clk * 1e-3,
        if_lat_ns=(h_if @ centers) / np.maximum(h_if.sum(axis=-1), 1.0)
            * 1e-3,
    )
    if rec.app_lat_cycles is None:
        raise ValueError("record lacks the application view; pass "
                         "outs=... to repro.obs.collect")
    out["app_lat_ns"] = (np.asarray(rec.app_lat_cycles[w0:], np.float64)
                         * rec.cpu_ps_per_clk * 1e-3)
    if rec.progress is not None:
        prog = np.asarray(rec.progress, np.float64).sum(axis=-1)
        inc = np.diff(prog, prepend=0.0)
        out["app_rate"] = inc[w0:]
    return out


def divergence(rec) -> dict:
    """One run's rank correlations between perspectives.

    The headline ``rho_sim_app`` is a *response* correlation: the
    stage-04 PI correction couples the application view to memory as
    an exponential smoother, so the app-view latency **level** is an
    integral of past memory latency (it rank-correlates poorly with
    the instantaneous series even when perfectly coupled, and is
    exactly constant in the broken stages), while its per-window
    **change** is proportional to the previous window's measured
    latency — `spearman(sim_lat[w], app_lat[w+1] - app_lat[w])` is ~0
    when the perspectives are decoupled (the app view never moves, no
    matter what the memory system does) and ~1 once the correction
    re-couples them.  The level correlations are reported alongside
    (``*_level``), as is the application *progress* coupling
    (``rho_sim_rate``: sim latency vs negated per-window progress
    rate, so "1 = re-coupled" reads the same in every column).
    """
    ser = window_series(rec)
    sim, ifl, app = (ser["sim_lat_ns"], ser["if_lat_ns"],
                     ser["app_lat_ns"])
    inno = np.diff(app)                        # app-view response
    out = dict(
        rho_sim_if=spearman(sim, ifl),
        rho_sim_app=spearman(sim[:-1], inno),
        rho_if_app=spearman(ifl[:-1], inno),
        rho_sim_app_level=spearman(sim, app),
        rho_if_app_level=spearman(ifl, app),
        sim_lat_ns_mean=float(sim.mean()),
        if_lat_ns_mean=float(ifl.mean()),
        app_lat_ns_mean=float(app.mean()),
    )
    if "app_rate" in ser:
        out["rho_sim_rate"] = spearman(sim, -ser["app_rate"])
    return out


def divergence_report(records_by_stage: dict, tol: float = 0.05) -> dict:
    """The correction-ladder divergence table (stages 01→10).

    Args:
        records_by_stage: ``{stage_name: TelemetryRecord}`` in ladder
            order (insertion order is kept).
        tol: tolerated per-step dip in ``rho_sim_app`` before the
            ladder is called non-monotone.
    Returns:
        ``{"ladder": [{stage, rho_sim_app, ...}, ...],
        "monotone_ok": bool, "exceptions": [...]}`` — the acceptance
        artifact: ``rho_sim_app`` must improve (weakly, within
        ``tol``) from the broken baseline to the fully-corrected
        stage, and any local dip is listed explicitly rather than
        hidden in an aggregate.
    """
    ladder = []
    for stage, rec in records_by_stage.items():
        row = dict(stage=stage)
        row.update(divergence(rec))
        ladder.append(row)
    exceptions = []
    for prev, cur in zip(ladder, ladder[1:]):
        if cur["rho_sim_app"] < prev["rho_sim_app"] - tol:
            exceptions.append(dict(
                from_stage=prev["stage"], to_stage=cur["stage"],
                drop=round(prev["rho_sim_app"] - cur["rho_sim_app"], 4)))
    first, last = ladder[0]["rho_sim_app"], ladder[-1]["rho_sim_app"]
    return dict(
        schema="repro.obs/perspectives-v1",
        ladder=ladder,
        monotone_ok=not exceptions and last >= first,
        end_to_end_gain=round(last - first, 4),
        exceptions=exceptions,
        tol=tol,
    )
