"""Telemetry collection and reduction (the simulator-view numbers).

`collect` snapshots a telemetry-enabled run (the flat ``tele_*`` keys
of the views dict) into a host-side `TelemetryRecord`; `summarize`
reduces it to the classical memory-controller statistics the Mess
methodology validates against: command mixes, row-buffer locality,
bank utilization, drain behavior, and latency percentiles.

Series conventions
------------------

All per-window series carry the **full** window axis ``W`` (warmup
included) so timelines start at t=0; reductions here slice
``warmup:`` themselves.  Keys and shapes (``C`` channels, ``RB``
banks/channel, ``B = dram.N_HIST`` log2 buckets):

==================== ============== =====================================
key                  shape          meaning
==================== ============== =====================================
``tele_n_act``       ``(W, C)``     ACT commands issued
``tele_n_pre``       ``(W, C)``     PRE commands issued (demand)
``tele_n_cas_rd``    ``(W, C)``     read CAS (== served reads)
``tele_n_cas_wr``    ``(W, C)``     write CAS (== served writes)
``tele_n_ref``       ``(W, C)``     refresh events (per-rank deadlines)
``tele_drain_enter`` ``(W, C)``     write-drain service bursts entered
``tele_drain_ticks`` ``(W, C)``     drain dwell (burst spans, at CAS)
``tele_busy_ticks``  ``(W, C, RB)`` row-open time (accounted at close)
``tele_hist_rd_ticks`` ``(W, C, B)`` read latency histogram, DRAM ticks
``tele_hist_if_ps``  ``(W, C, B)``  CPU-perceived read latency, ps
``tele_queue_depth`` ``(W, C)``     inject-queue depth after injection
``tele_mshr_budget`` ``(W,)``       MSHR closed-loop budget (requests)
``tele_lat_est_ps``  ``(W,)``       PI latency estimate (float ps)
==================== ============== =====================================

Histogram bucket ``b`` counts latencies in ``[2^b, 2^(b+1))`` —
integer-exact edges (`repro.core.dram.log2_bucket`).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.dram import N_HIST

#: the per-window telemetry series every telemetry-enabled views dict
#: carries (see the module docstring for shapes)
TELE_KEYS = (
    "tele_n_act", "tele_n_pre", "tele_n_cas_rd", "tele_n_cas_wr",
    "tele_n_ref", "tele_drain_enter", "tele_drain_ticks",
    "tele_busy_ticks", "tele_hist_rd_ticks", "tele_hist_if_ps",
    "tele_queue_depth", "tele_mshr_budget", "tele_lat_est_ps",
)


@dataclasses.dataclass
class TelemetryRecord:
    """One run's telemetry: host-side numpy series plus static context.

    ``series`` maps `TELE_KEYS` to arrays; ``app_lat_cycles`` /
    ``progress`` carry the application view when an `outs`
    (`repro.core.platform.WindowOut`) was supplied to `collect`.
    """

    stage: str
    windows: int
    warmup: int
    n_channels: int
    window_cycles: int
    cpu_ps_per_clk: int
    dram_ps_per_clk: int
    series: dict
    app_lat_cycles: np.ndarray | None = None   # (W,) bound-phase cycles
    progress: np.ndarray | None = None         # (W, n_cores) cursors

    def window_ps(self) -> int:
        """CPU picoseconds per window (the timeline step)."""
        return self.window_cycles * self.cpu_ps_per_clk


def collect(cfg, views, outs=None) -> TelemetryRecord:
    """Snapshot a telemetry-enabled run into a `TelemetryRecord`.

    Args:
        cfg: the run's `StageConfig` (must have ``telemetry=True``).
        views: the views dict from `repro.core.platform.run_frontend`
            (or any dict carrying the ``tele_*`` keys, e.g. a replay
            result row).
        outs: optionally the run's `WindowOut` trajectory; adds the
            application view (``app_lat_cycles``, ``progress``).
    """
    if not getattr(cfg, "telemetry", False):
        raise ValueError("telemetry is off in this StageConfig; rerun "
                         "with telemetry=True to collect planes")
    missing = [k for k in TELE_KEYS if k not in views]
    if missing:
        raise KeyError(f"views dict lacks telemetry keys {missing}")
    series = {k: np.asarray(views[k]) for k in TELE_KEYS}
    progress = None
    if outs is not None:
        # trace replay yields (W, n_cores) cursors; the Mess frontend a
        # scalar per-window marker — normalize to (W, K) for exporters
        progress = np.asarray(outs.progress)
        progress = progress.reshape(progress.shape[0], -1)
    return TelemetryRecord(
        stage=cfg.name, windows=cfg.windows, warmup=cfg.warmup,
        n_channels=cfg.platform.dram.n_channels,
        window_cycles=cfg.platform.cpu.window_cycles,
        cpu_ps_per_clk=cfg.platform.cpu.cpu_ps_per_clk,
        dram_ps_per_clk=cfg.platform.dram.dram_ps_per_clk,
        series=series,
        app_lat_cycles=(np.asarray(outs.app_lat_cycles)
                        if outs is not None else None),
        progress=progress)


def hist_edges(unit_ps: float = 1.0) -> np.ndarray:
    """The ``N_HIST + 1`` log2 bucket edges, scaled to picoseconds.

    Bucket ``b`` spans ``[edges[b], edges[b+1])``; pass the DRAM tick
    length to get simulator-view edges in ps, or 1.0 to keep the raw
    integer domain.
    """
    return (2.0 ** np.arange(N_HIST + 1)) * unit_ps


def hist_percentiles(hist, qs=(0.50, 0.95, 0.99)) -> np.ndarray:
    """Percentiles from a log2 histogram, linear within buckets.

    Args:
        hist: ``(..., N_HIST)`` integer counts; leading axes reduce
            by summation (e.g. windows and channels).
        qs: quantiles in ``(0, 1]``.
    Returns:
        ``(len(qs),)`` float estimates in the histogram's own unit
        (DRAM ticks or picoseconds); ``nan`` for an empty histogram.

    Buckets only bound each sample to ``[2^b, 2^(b+1))``, so the
    estimate interpolates the quantile's position linearly inside its
    bucket — exact at bucket boundaries, <= 2x off in the worst case
    (the bucket width), which is the standard log2-histogram
    trade-off (HdrHistogram-style).
    """
    h = np.asarray(hist, np.float64).reshape(-1, N_HIST).sum(axis=0)
    total = h.sum()
    if total <= 0:
        return np.full(len(tuple(qs)), np.nan)
    cum = np.cumsum(h)
    lo = 2.0 ** np.arange(N_HIST)
    out = []
    for q in qs:
        target = q * total
        b = int(np.searchsorted(cum, target))
        b = min(b, N_HIST - 1)
        prev = cum[b - 1] if b > 0 else 0.0
        frac = (target - prev) / max(h[b], 1e-12)
        out.append(lo[b] * (1.0 + min(max(frac, 0.0), 1.0)))
    return np.asarray(out)


def summarize(rec: TelemetryRecord) -> dict:
    """Reduce a record to the classical controller statistics.

    Post-warmup totals and rates: command mix, row-locality split by
    the one-CAS-per-request identity (``hits = cas - act``,
    ``misses = act - pre``, ``conflicts = pre``; refresh-forced
    re-ACTs can push per-window hits slightly negative, so the split
    is clamped at zero and the raw commands are reported alongside),
    bank-busy fraction, write-drain behavior, and latency percentiles
    from both latency histograms.
    """
    s = rec.series
    w0 = rec.warmup
    tot = lambda k: int(np.sum(s[k][w0:]))
    n_act, n_pre = tot("tele_n_act"), tot("tele_n_pre")
    n_rd, n_wr = tot("tele_n_cas_rd"), tot("tele_n_cas_wr")
    n_cas = n_rd + n_wr
    span = rec.windows - w0
    # simulator-view wall time of the reduced span, in DRAM ticks
    span_ticks = span * (rec.window_ps() // rec.dram_ps_per_clk)
    busy = np.asarray(s["tele_busy_ticks"][w0:], np.float64)
    p_rd = hist_percentiles(s["tele_hist_rd_ticks"][w0:])
    p_if = hist_percentiles(s["tele_hist_if_ps"][w0:])
    return dict(
        stage=rec.stage, windows=rec.windows, warmup=rec.warmup,
        commands=dict(act=n_act, pre=n_pre, cas_rd=n_rd, cas_wr=n_wr,
                      ref=tot("tele_n_ref")),
        row_locality=dict(
            hits=max(n_cas - n_act, 0),
            misses=max(n_act - n_pre, 0),
            conflicts=n_pre,
            hit_rate=(max(n_cas - n_act, 0) / n_cas) if n_cas else 0.0),
        bank_busy_frac=float(busy.sum(axis=0).mean()) / max(span_ticks, 1),
        drain=dict(entries=tot("tele_drain_enter"),
                   ticks=tot("tele_drain_ticks")),
        queue_depth_mean=float(np.mean(np.sum(
            s["tele_queue_depth"][w0:], axis=-1))),
        mshr_budget_mean=float(np.mean(s["tele_mshr_budget"][w0:])),
        lat_est_ns_final=float(s["tele_lat_est_ps"][-1]) * 1e-3,
        # percentiles: simulator view in ns (ticks x 750 ps), interface
        # view in ns (the histogram is already in CPU-perceived ps)
        sim_lat_ns=dict(zip(("p50", "p95", "p99"),
                            (p_rd * rec.dram_ps_per_clk * 1e-3).tolist())),
        if_lat_ns=dict(zip(("p50", "p95", "p99"), (p_if * 1e-3).tolist())),
    )
