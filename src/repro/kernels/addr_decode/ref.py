"""Pure-jnp oracle for the address-decode kernel.

Delegates to `repro.core.addrmap.decode_skylake_xor` — the mapping the
cycle-accurate simulator itself uses — so kernel == simulator by
construction when the test passes.
"""
from __future__ import annotations

from repro.core.addrmap import DecodedAddr, decode_skylake_xor


def decode_reference(lines) -> DecodedAddr:
    return decode_skylake_xor(lines)
