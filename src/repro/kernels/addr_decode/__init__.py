from repro.kernels.addr_decode.ops import decode_packed, decode_skylake, unpack
from repro.kernels.addr_decode.ref import decode_reference

__all__ = ["decode_packed", "decode_skylake", "unpack", "decode_reference"]
