"""Pallas TPU kernel: batched XOR-folded Skylake address decode.

Paper Sec. 4 / Fig. 6a: fidelity needs the reverse-engineered XOR
address mapping, applied to *every* memory request — in a vectorized
simulator that is a bulk bit-twiddling pass over millions of cache-line
indices per simulated window.  The kernel packs all five DRAM
coordinates into one uint32 per line (row 17b | col 7b | bank 4b |
rank 1b | channel 3b), keeping the output lane-aligned and letting the
caller unpack only the fields it needs.

Tiling: 1-D stream reshaped to (blocks, 1024) — 8 sublanes x 128 lanes
per VREG tile of int32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024

# packed-field shifts / widths
CH_SH, CH_W = 0, 3
RANK_SH, RANK_W = 3, 1
BANK_SH, BANK_W = 4, 4
COL_SH, COL_W = 8, 7
ROW_SH, ROW_W = 15, 17


def _bit(x, i):
    return (x >> jnp.uint32(i)) & jnp.uint32(1)


def _decode_kernel(line_ref, out_ref):
    line = line_ref[0].astype(jnp.uint32)
    mc = _bit(line, 0) ^ _bit(line, 6) ^ _bit(line, 11) ^ _bit(line, 17)
    ch3 = ((line >> 1) ^ (line >> 7) ^ (line >> 13) ^ (line >> 19)) % 3
    ch = mc * 3 + ch3
    bg0 = _bit(line, 2) ^ _bit(line, 12)
    bg1 = _bit(line, 3) ^ _bit(line, 14)
    ba0 = _bit(line, 4) ^ _bit(line, 15)
    ba1 = _bit(line, 5) ^ _bit(line, 16)
    bank = bg0 | (bg1 << 1) | (ba0 << 2) | (ba1 << 3)
    rank = _bit(line, 8) ^ _bit(line, 18)
    col = (line ^ (line >> 9)) % jnp.uint32(128)
    row = (line >> 9) & jnp.uint32(0x1FFFF)
    out_ref[0, :] = (ch
                     | (rank << RANK_SH)
                     | (bank << BANK_SH)
                     | (col << COL_SH)
                     | (row << ROW_SH)).astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_packed(lines, *, interpret: bool = True):
    """Decode (N,) uint32 cache-line indices -> (N,) packed coordinates."""
    n = lines.shape[0]
    n_pad = -(-n // BLOCK) * BLOCK
    x = jnp.pad(lines.astype(jnp.uint32), (0, n_pad - n))
    x = x.reshape(n_pad // BLOCK, BLOCK)
    out = pl.pallas_call(
        _decode_kernel,
        grid=(n_pad // BLOCK,),
        in_specs=[pl.BlockSpec((1, BLOCK), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad // BLOCK, BLOCK), jnp.uint32),
        interpret=interpret,
    )(x)
    return out.reshape(n_pad)[:n]


def unpack(packed):
    """Packed uint32 -> (channel, rank, bank, row, col) int32 fields."""
    p = packed.astype(jnp.uint32)
    field = lambda sh, w: ((p >> jnp.uint32(sh))
                           & jnp.uint32((1 << w) - 1)).astype(jnp.int32)
    return (field(CH_SH, CH_W), field(RANK_SH, RANK_W),
            field(BANK_SH, BANK_W), field(ROW_SH, ROW_W),
            field(COL_SH, COL_W))
