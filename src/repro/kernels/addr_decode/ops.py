"""Public wrapper for the address-decode kernel."""
from __future__ import annotations

from repro.core.addrmap import DecodedAddr
from repro.kernels.addr_decode.kernel import decode_packed, unpack


def decode_skylake(lines, *, interpret: bool = True) -> DecodedAddr:
    """(N,) uint32 cache-line indices -> DecodedAddr via the kernel."""
    ch, rank, bank, row, col = unpack(decode_packed(lines,
                                                    interpret=interpret))
    return DecodedAddr(ch, rank, bank, row, col)


__all__ = ["decode_skylake", "decode_packed", "unpack"]
