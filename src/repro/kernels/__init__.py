"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel package ships three modules:

* ``kernel.py`` — the ``pl.pallas_call`` body with explicit BlockSpec
  VMEM tiling (TPU is the target; ``interpret=True`` validates on CPU),
* ``ops.py``    — the jit'd public wrapper (padding, GQA folding,
  shape plumbing),
* ``ref.py``    — the pure-jnp oracle the tests sweep against.

Kernels:

* ``flash_attention`` — block-wise online-softmax attention (the LM
  substrate's prefill hot-spot; MXU-aligned 128x128 tiles).
* ``bank_timing``     — the cycle-accurate simulator's per-tick
  eligibility + FR-FCFS select (the paper engine's hot loop, a pure
  VPU workload: elementwise timing legality + masked argmax).
* ``addr_decode``     — batched XOR-folded Skylake address mapping
  (paper Sec. 4 / Fig. 6a) over cache-line indices.
"""
