"""Block-wise online-softmax attention (FlashAttention) for TPU.

TPU adaptation notes (vs the CUDA original):

* Tiles are MXU-aligned: ``block_q x d`` and ``block_k x d`` with
  d padded to a lane multiple (128).  The QK^T and PV matmuls both hit
  the 128x128 systolic array; the running max / denominator live in a
  float32 VMEM scratch accumulator (8x128-aligned), not registers.
* The KV loop is the innermost *grid* dimension — TPU grids execute
  sequentially per core, so VMEM scratch carries the online-softmax
  state between KV steps (the Pallas idiom replacing CUDA's intra-block
  loop + shared memory).
* Causal masking uses absolute positions with the decode convention
  (query i at position Sk - Sq + i).  Fully-masked KV blocks are
  computed-and-masked; the ops layer shrinks the grid instead when the
  shape allows it (hillclimb: see EXPERIMENTS.md §Perf).

Grid: ``(batch*heads, num_q_blocks, num_kv_blocks)``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")

# jax renamed TPUCompilerParams -> CompilerParams across releases; accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, sq: int, sk: int,
                  block_q: int, block_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)            # (bq, d)
    k = k_ref[0].astype(jnp.float32)            # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    # mask out-of-range keys (sequence padding) and the causal triangle
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < sk
    if causal:
        qpos = (qi * block_q + (sk - sq)
                + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0))
        mask &= kpos <= qpos
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                          # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows: exp(-inf - -inf) -> exp(0) must not fire
    safe_m = jnp.where(m_new == NEG_INF, 0.0, m_new)
    p = jnp.exp(jnp.where(mask, s - safe_m, NEG_INF))
    alpha = jnp.exp(jnp.where(m_prev == NEG_INF, NEG_INF, m_prev - safe_m))

    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    v = v_ref[0].astype(jnp.float32)            # (bk, d)
    acc_ref[...] = (alpha * acc_ref[...]
                    + jax.lax.dot_general(
                        p, v, (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0, :, :] = (acc_ref[...]
                          / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "scale", "block_q", "block_k",
                              "interpret"))
def flash_attention_bhsd(q, k, v, *, causal: bool = False,
                         scale: float | None = None,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = True):
    """Flash attention over flattened heads.

    q: (BH, Sq, D); k, v: (BH, Sk, D), all pre-padded so that
    Sq % block_q == Sk % block_k == 0 is NOT required — padding is
    handled here.  Returns (BH, Sq, D).
    """
    bh, sq, d = q.shape
    sk = k.shape[1]
    if scale is None:
        scale = 1.0 / (d ** 0.5)

    sq_p = -(-sq // block_q) * block_q
    sk_p = -(-sk // block_k) * block_k
    d_p = max(-(-d // 128) * 128, 128)
    pad3 = lambda x, s, dd: jnp.pad(
        x, ((0, 0), (0, s - x.shape[1]), (0, dd - x.shape[2])))
    qp, kp, vp = pad3(q, sq_p, d_p), pad3(k, sk_p, d_p), pad3(v, sk_p, d_p)

    grid = (bh, sq_p // block_q, sk_p // block_k)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          sq=sq, sk=sk, block_q=block_q, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d_p), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d_p), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d_p), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d_p), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq_p, d_p), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max
            pltpu.VMEM((block_q, 1), jnp.float32),    # running denom
            pltpu.VMEM((block_q, d_p), jnp.float32),  # output accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :sq, :d]
