"""Public flash-attention op: GQA folding + head flattening."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


@functools.partial(
    jax.jit, static_argnames=("causal", "scale", "block_q", "block_k",
                              "interpret"))
def flash_attention(q, k, v, *, causal: bool = False,
                    scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """GQA flash attention.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D), Hq % Hkv == 0.
    Returns (B, Hq, Sq, D) in q.dtype.
    """
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0, (hq, hkv)
    if hkv != hq:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    flat = lambda x: x.reshape(b * hq, x.shape[2], d)
    o = flash_attention_bhsd(flat(q), flat(k), flat(v), causal=causal,
                             scale=scale, block_q=block_q, block_k=block_k,
                             interpret=interpret)
    return o.reshape(b, hq, sq, d)
