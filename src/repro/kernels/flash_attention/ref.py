"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax.numpy as jnp


def mha_reference(q, k, v, *, causal: bool = False, scale: float | None = None):
    """Multi-head attention, O(S^2) materialized — the correctness oracle.

    q: (B, Hq, Sq, D);  k, v: (B, Hkv, Sk, D) with Hq % Hkv == 0 (GQA).
    Returns (B, Hq, Sq, D) in q.dtype; softmax in float32.
    """
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    if hkv != hq:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        # decode convention: query i sits at absolute position
        # (Sk - Sq + i), so the last query row attends to all keys.
        qpos = jnp.arange(sq)[:, None] + (sk - sq)
        kpos = jnp.arange(sk)[None, :]
        s = jnp.where(kpos <= qpos, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
