from repro.kernels.bank_timing.ops import (ChannelScalars, frfcfs_select,
                                           pack_scalars, scalars_tuple)
from repro.kernels.bank_timing.ref import select_reference

__all__ = ["ChannelScalars", "frfcfs_select", "pack_scalars",
           "scalars_tuple", "select_reference"]
