"""Pallas TPU kernel: per-tick DRAM eligibility + FR-FCFS select.

The cycle-accurate simulator spends its time in one block: checking the
DDR4 timing legality of every queued request and picking the winner
(row-hit CAS > ACT > PRE, oldest first).  On TPU this is a pure VPU
workload — elementwise compares over a (channels, queue) tile and a
masked argmax along lanes.  One grid step processes one channel; the
queue axis (256 slots = 2x128 lanes) is the lane dimension, so the
whole eligibility computation is one VREG-resident dataflow with no
HBM traffic beyond the initial tile loads.

Hardware adaptation: the C++ simulators walk linked-list queues a
request at a time; the TPU formulation evaluates *all* slots per cycle
in parallel and reduces.  That is the same algorithm (priority order is
encoded in the score), vectorized.

Inputs: eleven (C, Q) int32 planes (gathered per-entry state) plus one
(C, 8) scalar plane; outputs (C, 2) int32 = (selected slot, command).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BIG = 1 << 28      # python int: becomes an immediate, not a captured const
NONE, RD, WR, ACT, PRE = 0, 1, 2, 3, 4

# scalar plane columns
T, BUS_FREE, WTR, RTW, DRAIN, STREAK = range(6)
N_SCALARS = 8   # padded


def _select_kernel(arrived_ref, is_write_ref, row_ref, open_ref, nrd_ref,
                   nwr_ref, nact_ref, npre_ref, faw_ref, hitp_ref,
                   arrival_ref, ch_ref, out_ref, *, row_hit_cap: int,
                   queue_depth: int):
    arrived = arrived_ref[0] == 1                     # (Q,)
    is_wr = is_write_ref[0] == 1
    row = row_ref[0]
    open_e = open_ref[0]
    t = ch_ref[0, T]
    bus_ok = t >= ch_ref[0, BUS_FREE]
    wtr_ok = t >= ch_ref[0, WTR]
    rtw_ok = t >= ch_ref[0, RTW]
    drain = ch_ref[0, DRAIN] == 1
    streak = ch_ref[0, STREAK]

    row_hit = (open_e == row) & arrived
    closed = (open_e < 0) & arrived
    side_ok = jnp.where(is_wr, drain, ~drain)
    elig_rd = row_hit & ~is_wr & (t >= nrd_ref[0]) & bus_ok & wtr_ok & ~drain
    elig_wr = row_hit & is_wr & (t >= nwr_ref[0]) & bus_ok & rtw_ok & drain
    elig_act = closed & (t >= nact_ref[0]) & (faw_ref[0] == 1) & side_ok
    elig_pre = (arrived & (open_e >= 0) & (open_e != row)
                & (t >= npre_ref[0]) & (hitp_ref[0] == 0) & side_ok)

    age = _BIG - arrival_ref[0]
    score = jnp.where(elig_rd | elig_wr, 3 * _BIG + age,
             jnp.where(elig_act, 2 * _BIG + age,
              jnp.where(elig_pre, 1 * _BIG + age, 0)))
    if row_hit_cap > 0:
        capped = streak >= row_hit_cap
        score = jnp.where(capped & (elig_rd | elig_wr), 1 * _BIG + age, score)
        score = jnp.where(capped & elig_act, 3 * _BIG + age, score)

    sel = jnp.argmax(score, axis=0).astype(jnp.int32)
    onehot = jax.lax.broadcasted_iota(jnp.int32, (queue_depth,), 0) == sel
    pick = lambda m: jnp.max(jnp.where(onehot, m.astype(jnp.int32), 0))
    any_cmd = pick(score) > 0
    s_rd_ok = pick(elig_rd) == 1
    s_wr_ok = pick(elig_wr) == 1
    s_act_ok = pick(elig_act) == 1
    s_pre_ok = pick(elig_pre) == 1
    if row_hit_cap > 0:
        capped1 = streak >= row_hit_cap
        s_cas = any_cmd & (s_rd_ok | s_wr_ok) & ~(capped1 & s_act_ok)
        s_act = any_cmd & s_act_ok & ~s_cas
    else:
        s_cas = any_cmd & (s_rd_ok | s_wr_ok)
        s_act = any_cmd & s_act_ok & ~s_cas
    s_pre = any_cmd & s_pre_ok & ~s_cas & ~s_act
    s_iswr = pick(is_wr) == 1

    cmd = jnp.where(s_cas & ~s_iswr, RD,
           jnp.where(s_cas & s_iswr, WR,
            jnp.where(s_act, ACT,
             jnp.where(s_pre, PRE, NONE)))).astype(jnp.int32)
    out_ref[0, 0] = sel
    out_ref[0, 1] = cmd


@functools.partial(jax.jit,
                   static_argnames=("row_hit_cap", "interpret"))
def frfcfs_select(arrived, is_write, row, open_e, nrd_e, nwr_e, nact_e,
                  npre_e, faw_ok, hit_pend, arrival, ch_scalars, *,
                  row_hit_cap: int = 0, interpret: bool = True):
    """Pallas twin of the select block in `repro.core.dram.tick`.

    Per-entry planes: (C, Q) int32.  ch_scalars: (C, 8) int32 with
    columns (t, bus_free, wtr_until, rtw_until, drain, hit_streak).
    Returns (sel, cmd), each (C,) int32.
    """
    C, Q = arrived.shape
    planes = [arrived, is_write, row, open_e, nrd_e, nwr_e, nact_e,
              npre_e, faw_ok, hit_pend, arrival]
    out = pl.pallas_call(
        functools.partial(_select_kernel, row_hit_cap=row_hit_cap,
                          queue_depth=Q),
        grid=(C,),
        in_specs=[pl.BlockSpec((1, Q), lambda c: (c, 0))] * len(planes)
                 + [pl.BlockSpec((1, N_SCALARS), lambda c: (c, 0))],
        out_specs=pl.BlockSpec((1, 2), lambda c: (c, 0)),
        out_shape=jax.ShapeDtypeStruct((C, 2), jnp.int32),
        interpret=interpret,
    )(*planes, ch_scalars)
    return out[:, 0], out[:, 1]
