"""Public wrapper for the FR-FCFS select kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.bank_timing.kernel import frfcfs_select
from repro.kernels.bank_timing.ref import ChannelScalars


def pack_scalars(t, bus_free, wtr_until, rtw_until, drain,
                 hit_streak) -> jnp.ndarray:
    """Pack per-channel scalars into the kernel's (C, 8) plane."""
    C = bus_free.shape[0]
    t = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (C,))
    cols = [t, bus_free, wtr_until, rtw_until,
            drain.astype(jnp.int32), hit_streak]
    pad = [jnp.zeros((C,), jnp.int32)] * (8 - len(cols))
    return jnp.stack([c.astype(jnp.int32) for c in cols] + pad, axis=1)


def scalars_tuple(ch_plane: jnp.ndarray) -> ChannelScalars:
    """Unpack the (C, 8) plane into the ref oracle's NamedTuple."""
    return ChannelScalars(*(ch_plane[:, i] for i in range(6)))


__all__ = ["frfcfs_select", "pack_scalars", "scalars_tuple",
           "ChannelScalars"]
