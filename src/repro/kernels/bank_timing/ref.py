"""Pure-jnp oracle for the FR-FCFS eligibility + select kernel.

This mirrors — input for input — the eligibility/priority block inside
`repro.core.dram.tick` (the cycle-accurate simulator's hot loop).  The
integration test in tests/test_kernels.py rebuilds these gathered
fields from a live (QueueState, BankState) pair exactly the way
`dram.tick` does and asserts the same command selection.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

_BIG = jnp.int32(1 << 28)

# command codes (match repro.core.dram)
NONE, RD, WR, ACT, PRE = 0, 1, 2, 3, 4


class ChannelScalars(NamedTuple):
    """Per-channel scalar state, all (C,) int32."""

    t: jnp.ndarray            # current DRAM tick (broadcast)
    bus_free: jnp.ndarray
    wtr_until: jnp.ndarray
    rtw_until: jnp.ndarray
    drain: jnp.ndarray        # 0/1
    hit_streak: jnp.ndarray


def select_reference(arrived, is_write, row, open_e, nrd_e, nwr_e, nact_e,
                     npre_e, faw_ok, hit_pend, arrival,
                     ch: ChannelScalars, *, row_hit_cap: int = 0):
    """FR-FCFS select over per-entry gathered fields.

    All per-entry args are (C, Q) int32 (masks are 0/1).  Returns
    (sel, cmd): per-channel selected queue index and command code.
    """
    t = ch.t[:, None]
    row_hit = (open_e == row) & (arrived == 1)
    closed = (open_e < 0) & (arrived == 1)
    is_wr = is_write == 1
    bus_ok = (ch.t >= ch.bus_free)[:, None]
    drain_c = (ch.drain == 1)[:, None]

    side_ok = jnp.where(is_wr, drain_c, ~drain_c)
    elig_rd = (row_hit & ~is_wr & (t >= nrd_e) & bus_ok
               & (ch.t >= ch.wtr_until)[:, None] & ~drain_c)
    elig_wr = (row_hit & is_wr & (t >= nwr_e) & bus_ok
               & (ch.t >= ch.rtw_until)[:, None] & drain_c)
    elig_act = closed & (t >= nact_e) & (faw_ok == 1) & side_ok
    elig_pre = ((arrived == 1) & (open_e >= 0) & (open_e != row)
                & (t >= npre_e) & (hit_pend == 0) & side_ok)

    age = _BIG - arrival
    score = jnp.where(elig_rd | elig_wr, 3 * _BIG + age,
             jnp.where(elig_act, 2 * _BIG + age,
              jnp.where(elig_pre, 1 * _BIG + age, 0)))
    if row_hit_cap > 0:
        capped = (ch.hit_streak >= row_hit_cap)[:, None]
        score = jnp.where(capped & (elig_rd | elig_wr), 1 * _BIG + age, score)
        score = jnp.where(capped & elig_act, 3 * _BIG + age, score)

    sel = jnp.argmax(score, axis=1)
    pick = lambda f: jnp.take_along_axis(f, sel[:, None], 1)[:, 0]
    any_cmd = pick(score) > 0
    s_rd_ok = pick(elig_rd.astype(jnp.int32)) == 1
    s_wr_ok = pick(elig_wr.astype(jnp.int32)) == 1
    s_act_ok = pick(elig_act.astype(jnp.int32)) == 1
    s_pre_ok = pick(elig_pre.astype(jnp.int32)) == 1
    if row_hit_cap > 0:
        capped1 = ch.hit_streak >= row_hit_cap
        s_cas = any_cmd & (s_rd_ok | s_wr_ok) & ~(capped1 & s_act_ok)
        s_act = any_cmd & s_act_ok & ~s_cas
    else:
        s_cas = any_cmd & (s_rd_ok | s_wr_ok)
        s_act = any_cmd & s_act_ok & ~s_cas
    s_pre = any_cmd & s_pre_ok & ~s_cas & ~s_act
    s_iswr = pick(is_write) == 1

    cmd = jnp.where(s_cas & ~s_iswr, RD,
           jnp.where(s_cas & s_iswr, WR,
            jnp.where(s_act, ACT,
             jnp.where(s_pre, PRE, NONE))))
    return sel.astype(jnp.int32), cmd.astype(jnp.int32)
