"""Production training launcher.

On a real cluster this process runs per host under
``jax.distributed.initialize()``; here it demonstrates the full wiring
on the local device(s): mesh + logical rules -> sharded params/opt
state -> pjit train step -> fault-tolerant loop.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --smoke --steps 20
(--smoke uses the reduced config; without it the full config is used,
which requires real accelerator capacity.)
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import registry as cfgs
from repro.data.synthetic import DataConfig, Stream
from repro.launch.mesh import make_host_mesh, rules_for
from repro.models.registry import count_params, get_model
from repro.parallel.axes import sharding_rules
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(cfgs.ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = (cfgs.get_smoke(args.arch) if args.smoke
           else cfgs.get_config(args.arch))
    mesh = make_host_mesh()
    with sharding_rules(mesh, rules_for(mesh)):
        api = get_model(cfg)
        trainer = Trainer(
            api,
            AdamWConfig(lr=3e-4, warmup_steps=10, total_steps=args.steps),
            TrainerConfig(total_steps=args.steps,
                          ckpt_every=max(10, args.steps // 2),
                          ckpt_dir=args.ckpt_dir, log_every=10,
                          compress_grads=args.compress_grads))
        print(f"[launch.train] {cfg.name}: "
              f"{count_params(trainer.params) / 1e6:.1f}M params on "
              f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}")
        trainer.maybe_resume()
        data = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch)
        stream = Stream(data)
        stream.seek(trainer.step_idx)
        res = trainer.fit(stream)
        print(f"[launch.train] finished at step {res['final_step']}; "
              f"loss {res['losses'][0]:.3f} -> {res['losses'][-1]:.3f}")


if __name__ == "__main__":
    main()
