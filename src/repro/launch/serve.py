"""Production serving launcher: Engine over the host mesh.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch whisper-large-v3 \
      --smoke --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry as cfgs
from repro.launch.mesh import make_host_mesh, rules_for
from repro.models.registry import get_model
from repro.parallel.axes import sharding_rules
from repro.serve.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(cfgs.ARCHS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args()

    cfg = cfgs.get_smoke(args.arch)
    mesh = make_host_mesh()
    with sharding_rules(mesh, rules_for(mesh)):
        api = get_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        ctx = None
        if api.needs_ctx:
            ctx = jnp.asarray(
                np.random.default_rng(0).standard_normal(
                    (args.slots, cfg.n_ctx_tokens, cfg.d_model)),
                jnp.float32)
        eng = Engine(api, params, n_slots=args.slots,
                     max_seq=args.max_seq, ctx=ctx)
        rng = np.random.default_rng(1)
        for i in range(args.requests):
            eng.submit(Request(
                rid=i,
                prompt=list(rng.integers(1, cfg.vocab, 4)),
                max_new=8))
        t0 = time.perf_counter()
        done = eng.run()
        dt = time.perf_counter() - t0
        toks = sum(len(r.out) for r in done)
        print(f"[launch.serve] {cfg.name}: {len(done)} requests, "
              f"{toks} tokens, {toks / dt:.1f} tok/s")


if __name__ == "__main__":
    main()
