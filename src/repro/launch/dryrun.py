import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above run before ANY other import — jax locks the device
count at first init, and the production meshes need 512 host devices.
Nothing else in the repo sets this flag (smoke tests and benches see
the real device count).

Per cell this script:
  1. builds the arch's ModelApi and the step for the shape's kind
     (train_step / prefill forward / serve decode step),
  2. lowers it under the production mesh with explicit in/out
     shardings derived from each model's logical spec trees,
  3. compiles, prints ``memory_analysis()`` (proves the per-chip
     footprint) and ``cost_analysis()`` (FLOPs/bytes for §Roofline),
  4. parses collective bytes out of the partitioned HLO and writes the
     roofline record to ``reports/dryrun/<mesh>/<arch>__<shape>.json``.

Usage:
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import registry as cfgs
from repro.configs.shapes import SHAPES
from repro.launch.mesh import make_production_mesh, rules_for
from repro.models.registry import get_model
from repro.parallel.axes import (resolve, sharding_rules,
                                 spec_tree_to_shardings)
from repro.perfmodel import hlo_cost
from repro.perfmodel import roofline as roof
from repro.train import optimizer as opt
from repro.train.step import batch_specs, build_train_step

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "reports", "dryrun")

#: gradient-accumulation factor per arch for train_4k (bounds
#: activation memory; microbatch = 256/accum global).
TRAIN_ACCUM = {
    "qwen2-72b": 16, "arctic-480b": 16, "grok-1-314b": 16,
    "minitron-8b": 8, "llama-3.2-vision-11b": 8,
}
DEFAULT_ACCUM = 4

#: bf16 Adam moments for archs whose fp32 m+v would not fit 16 GB/chip
BF16_OPT_STATE = {"arctic-480b", "grok-1-314b"}


def input_structs(api, shape, *, for_train: bool):
    cfg = api.cfg
    gb, seq = shape.global_batch, shape.seq_len
    s = lambda sh, dt: jax.ShapeDtypeStruct(sh, dt)
    batch = dict(tokens=s((gb, seq), jnp.int32))
    if for_train:
        batch["labels"] = s((gb, seq), jnp.int32)
    if api.needs_ctx:
        batch["ctx"] = s((gb, cfg.n_ctx_tokens, cfg.d_model), cfg.dtype)
    return batch


def _shardings_for_batch(api, batch_struct):
    spec = dict(tokens=("batch", None))
    if "labels" in batch_struct:
        spec["labels"] = ("batch", None)
    if "ctx" in batch_struct:
        spec["ctx"] = ("batch", None, None)
    return spec_tree_to_shardings(spec, batch_struct)


def build_cell(api, shape, serving: bool = False):
    """Returns (fn, arg_structs, in_shardings, out_shardings)."""
    cfg = api.cfg
    params_struct = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    if serving:
        # §Perf iteration 2: serving stores parameters in bf16 —
        # halves resident weight memory and any residual weight traffic
        params_struct = jax.tree_util.tree_map(
            lambda s: (jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
                       if s.dtype == jnp.float32 else s), params_struct)
    p_shard = spec_tree_to_shardings(api.param_specs(), params_struct)

    if shape.kind == "train":
        accum = TRAIN_ACCUM.get(cfg.name, DEFAULT_ACCUM)
        ocfg = opt.AdamWConfig(
            state_dtype=(jnp.bfloat16 if cfg.name in BF16_OPT_STATE
                         else jnp.float32))
        ostate_struct = jax.eval_shape(
            lambda p: opt.init_state(ocfg, p), params_struct)
        o_shard = spec_tree_to_shardings(
            opt.state_specs(api.param_specs()), ostate_struct)
        batch_struct = input_structs(api, shape, for_train=True)
        b_shard = _shardings_for_batch(api, batch_struct)
        step = build_train_step(api, ocfg, accum=accum)
        return (step, (params_struct, ostate_struct, batch_struct),
                (p_shard, o_shard, b_shard), (p_shard, o_shard, None))

    if shape.kind == "prefill":
        batch_struct = input_structs(api, shape, for_train=False)
        b_shard = _shardings_for_batch(api, batch_struct)
        fwd = lambda p, b: api.forward(p, b)
        return (fwd, (params_struct, batch_struct),
                (p_shard, b_shard), None)

    # decode
    gb = shape.global_batch
    cache_struct = jax.eval_shape(
        lambda: api.init_cache(gb, shape.seq_len))
    shard_seq = True
    c_shard = spec_tree_to_shardings(
        api.cache_specs(shard_seq=shard_seq), cache_struct)
    tok_struct = jax.ShapeDtypeStruct((gb,), jnp.int32)
    t_shard = spec_tree_to_shardings(("batch",), tok_struct)
    step = lambda p, c, t: api.decode(p, c, t)
    return (step, (params_struct, cache_struct, tok_struct),
            (p_shard, c_shard, t_shard), (None, c_shard))


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             report_dir: str = REPORT_DIR, force: bool = False,
             verbose: bool = True, variant: str = "baseline") -> dict:
    mesh_name = "multipod" if multi_pod else "pod"
    outdir = os.path.join(
        report_dir + ("_opt" if variant == "opt" else ""), mesh_name)
    os.makedirs(outdir, exist_ok=True)
    outfile = os.path.join(outdir, f"{arch}__{shape_name}.json")
    if os.path.exists(outfile) and not force:
        with open(outfile) as f:
            return json.load(f)

    shape = SHAPES[shape_name]
    cfg = cfgs.get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    # §Perf iteration 2: serving cells use the weight-stationary
    # layout under the 'opt' variant (see parallel.axes.serve_rules)
    serving = variant == "opt" and shape.kind == "decode"
    with sharding_rules(mesh, rules_for(mesh, serving=serving)):
        api = get_model(cfg)
        fn, structs, in_sh, out_sh = build_cell(api, shape,
                                                serving=serving)
        with mesh:
            # decode donates the cache (in-place update on device);
            # train donates params+opt state — standard production
            # aliasing, and it is what keeps the per-chip footprint
            # at (args + working set) instead of 2x.
            donate = {"decode": (1,), "train": (0, 1)}.get(
                shape.kind, ())
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*structs)
            compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost_raw = compiled.cost_analysis() or {}
    text = compiled.as_text()
    # cache the partitioned HLO (zstd) so cost-model improvements can
    # re-analyze without recompiling (scripts/reanalyze.py)
    try:
        import zstandard
        with open(outfile.replace(".json", ".hlo.zst"), "wb") as f:
            f.write(zstandard.ZstdCompressor(level=9).compress(
                text.encode()))
    except Exception:
        pass
    # trip-count-aware HLO cost model (cost_analysis counts while
    # bodies once; a scan-over-layers step is undercounted ~L x)
    parsed = hlo_cost.analyze(text)
    cost = {"flops": parsed["flops"], "bytes accessed": parsed["bytes"]}
    coll = parsed

    params_struct = structs[0]
    n_active = roof.count_active_params(
        params_struct, cfg.top_k, cfg.n_experts)
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind in ("train", "prefill")
              else shape.global_batch)
    mflops = roof.model_flops(shape.kind, n_active, tokens)

    bytes_per_dev = float(getattr(mem, "temp_size_in_bytes", 0)
                          + getattr(mem, "argument_size_in_bytes", 0))
    r = roof.make(arch, shape_name, mesh_name, chips, cost=cost,
                  collectives=coll, model_flops=mflops,
                  bytes_per_device=bytes_per_dev)
    record = dict(r.as_dict(), compile_s=t_compile,
                  collectives=dict(bytes_by_op=coll["bytes_by_op"],
                                   counts=coll["counts"],
                                   total_bytes=coll["total_bytes"]),
                  cost_analysis_raw={k: float(v)
                                     for k, v in cost_raw.items()
                                     if isinstance(v, (int, float))},
                  n_params=roof.count_params_struct(params_struct),
                  n_active_params=n_active,
                  memory_analysis=dict(
                      temp=float(getattr(mem, "temp_size_in_bytes", 0)),
                      args=float(getattr(mem, "argument_size_in_bytes", 0)),
                      output=float(getattr(mem, "output_size_in_bytes", 0)),
                  ))
    with open(outfile, "w") as f:
        json.dump(record, f, indent=1)
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: "
              f"compile {t_compile:.0f}s  "
              f"mem/dev {bytes_per_dev / 2**30:.2f} GiB  "
              f"compute {r.compute_s * 1e3:.2f} ms  "
              f"memory {r.memory_s * 1e3:.2f} ms  "
              f"collective {r.collective_s * 1e3:.2f} ms  "
              f"-> {r.bottleneck}", flush=True)
        print(f"  memory_analysis: {mem}", flush=True)
        ck = {k: v for k, v in cost.items()
              if k in ("flops", "bytes accessed")}
        print(f"  cost_analysis: {ck}", flush=True)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--report-dir", default=REPORT_DIR)
    ap.add_argument("--variant", choices=("baseline", "opt"),
                    default="baseline")
    args = ap.parse_args()

    if args.all:
        cells = cfgs.cells()
        if args.arch:
            cells = [c for c in cells if c[0] == args.arch]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = {"single": (False,), "multi": (True,),
              "both": (False, True)}[args.mesh]
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                run_cell(arch, shape, mp, report_dir=args.report_dir,
                         force=args.force, variant=args.variant)
            except Exception as e:       # noqa: BLE001
                failures.append((arch, shape, mp, repr(e)))
                print(f"[dryrun] FAIL {arch} x {shape} "
                      f"(multi_pod={mp}): {e}", flush=True)
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed")
    print("[dryrun] all requested cells compiled OK")


if __name__ == "__main__":
    main()
