"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any
jax import; everything else sees the real device count).
"""
from __future__ import annotations

import jax

from repro.parallel.axes import (multi_pod_rules, serve_rules,
                                 single_pod_rules)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def rules_for(mesh, *, serving: bool = False) -> dict:
    multi = "pod" in mesh.axis_names
    if serving:
        return serve_rules(multi_pod=multi)
    return multi_pod_rules() if multi else single_pod_rules()


def make_host_mesh():
    """Degenerate 1x1 mesh over the real local device (smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
