"""zamba2-2.7b [hybrid] — Mamba2 + shared attn blocks [arXiv:2411.15242].

54L d_model=2560 32H (kv=32) d_ff=10240, ssm_state=64.  54 Mamba2
blocks with one SHARED attention+MLP transformer block applied every
6 layers (params shared, per-application KV caches).  Mamba2 state is
O(1) in sequence length -> runs the 500k cell; the shared block's KV
cache at 500k is sequence-sharded over the data axis
(flash-decoding-style partial-softmax combine).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000, ssm_state=64, ssm_expand=2,
    ssm_head_dim=64, attn_every=6, d_head=80,
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, ssm_state=8, ssm_expand=2,
    ssm_head_dim=16, ssm_chunk=8, attn_every=2, d_head=16,
)

SKIP_SHAPES: set = set()     # SSM backbone -> long_500k runs
