"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib

from repro.configs.shapes import SHAPES, SHAPE_ORDER, ShapeConfig  # noqa

ARCHS = {
    "tinyllama-1.1b": "repro.configs.tinyllama_1_1b",
    "minitron-8b": "repro.configs.minitron_8b",
    "qwen2-72b": "repro.configs.qwen2_72b",
    "deepseek-7b": "repro.configs.deepseek_7b",
    "xlstm-1.3b": "repro.configs.xlstm_1_3b",
    "llama-3.2-vision-11b": "repro.configs.llama32_vision_11b",
    "arctic-480b": "repro.configs.arctic_480b",
    "grok-1-314b": "repro.configs.grok1_314b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
}

ARCH_ORDER = tuple(ARCHS)


def _module(arch: str):
    try:
        return importlib.import_module(ARCHS[arch])
    except KeyError:
        raise ValueError(
            f"unknown arch {arch!r}; one of {list(ARCHS)}") from None


def get_config(arch: str):
    return _module(arch).CONFIG


def get_smoke(arch: str):
    return _module(arch).SMOKE


def skip_shapes(arch: str) -> set:
    return set(_module(arch).SKIP_SHAPES)


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells in canonical order."""
    out = []
    for a in ARCH_ORDER:
        skips = skip_shapes(a)
        for s in SHAPE_ORDER:
            if include_skipped or s not in skips:
                out.append((a, s))
    return out
