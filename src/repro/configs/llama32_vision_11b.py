"""llama-3.2-vision-11b [vlm] — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision].

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256; every 5th
layer is a gated cross-attention layer over precomputed image patch
embeddings (vision frontend is a STUB per the assignment:
input_specs() provides the patch embeddings).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128256, cross_attn_every=5,
    n_ctx_tokens=1600, rope_theta=5e5,
)

SMOKE = ModelConfig(
    name="llama32v-smoke", family="vlm",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=160, vocab=256, cross_attn_every=2, n_ctx_tokens=8,
)

SKIP_SHAPES = {"long_500k"}   # full self-attention backbone
