"""minitron-8b [dense] — pruned nemotron [arXiv:2407.14679; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=16384, vocab=256000, d_head=128, rope_theta=1e4,
)

SMOKE = ModelConfig(
    name="minitron-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
    d_ff=256, vocab=512, d_head=16,
)

SKIP_SHAPES = {"long_500k"}
