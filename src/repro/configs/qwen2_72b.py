"""qwen2-72b [dense] — GQA, QKV bias [arXiv:2407.10671; hf].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab=152064, qkv_bias=True, rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="qwen2-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=192, vocab=512, qkv_bias=True,
)

SKIP_SHAPES = {"long_500k"}
