"""arctic-480b [moe] — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base].

35L d_model=7168 56H (GQA kv=8) d_ff=4864, MoE 128e top-2 with a dense
SwiGLU residual in parallel (Arctic's dense-MoE hybrid).  Adam moments
run in bf16 for this arch (fp32 m+v would exceed 16 GB/chip even fully
sharded — see DESIGN.md §memory budget).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab=32000, n_experts=128, top_k=2,
    dense_residual=True, rope_theta=1e4,
)

SMOKE = ModelConfig(
    name="arctic-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=96, vocab=256, n_experts=4, top_k=2, dense_residual=True,
)

SKIP_SHAPES = {"long_500k"}   # full-attention MoE
