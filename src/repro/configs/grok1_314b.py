"""grok-1-314b [moe] — 8 experts top-2 [hf:xai-org/grok-1].

64L d_model=6144 48H (GQA kv=8) d_ff=32768, MoE 8e top-2,
vocab=131072, 30.0 attention-logit softcap (grok's tanh capping).
8 experts do not divide the 16-way 'model' axis -> the sharding rules
fall back to TP *within* experts (d_ff 32768/16) automatically.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab=131072, n_experts=8, top_k=2,
    attn_logit_softcap=30.0, rope_theta=1e4,
)

SMOKE = ModelConfig(
    name="grok1-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=192, vocab=256, n_experts=4, top_k=2,
    attn_logit_softcap=30.0,
)

SKIP_SHAPES = {"long_500k"}   # full-attention MoE
