"""The assigned input-shape set (same four for every LM arch).

``train_*`` lowers train_step; ``prefill_*`` lowers the forward pass;
``decode_*`` / ``long_*`` lower serve_step (one new token against a KV
cache / recurrent state of ``seq_len``).  ``long_500k`` requires
sub-quadratic attention — pure full-attention archs skip it (recorded
per arch in its config module and in DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}

SHAPE_ORDER = tuple(SHAPES)
