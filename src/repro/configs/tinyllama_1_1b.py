"""tinyllama-1.1b [dense] — llama2-arch small [arXiv:2401.02385; hf].

22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b", family="dense",
    n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=5632, vocab=32000, rope_theta=1e4,
)

SMOKE = ModelConfig(
    name="tinyllama-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=160, vocab=256,
)

#: pure full attention (quadratic) -> no 500k-token decode
SKIP_SHAPES = {"long_500k"}
