"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].

48L d_model=2048 4H (kv=4) d_ff=0 (projections live inside the
m/sLSTM blocks) vocab=50304.  Segment layout: 7 mLSTM + 1 sLSTM per
8 layers.  O(1)-state decode -> runs the 500k-token cell.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304, slstm_every=8, ssm_expand=2,
)

SMOKE = ModelConfig(
    name="xlstm-smoke", family="ssm",
    n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
    d_ff=0, vocab=256, slstm_every=2, ssm_expand=2,
)

SKIP_SHAPES: set = set()     # recurrent decode -> long_500k runs
