"""whisper-large-v3 [audio] — enc-dec, conv frontend (stub)
[arXiv:2212.04356].

32L (decoder; 32-layer encoder) d_model=1280 20H (MHA kv=20)
d_ff=5120 vocab=51866.  The conv frontend is a STUB: input_specs()
provides precomputed frame embeddings (B, 1500, d).  20 heads do not
divide the 16-way 'model' axis -> heads replicate, the 5120-wide FFN
carries the TP (divisibility fallback, DESIGN.md §Arch-applicability).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab=51866, n_encoder_layers=32, n_ctx_tokens=1500,
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, n_encoder_layers=2, n_ctx_tokens=8,
)

SKIP_SHAPES = {"long_500k"}   # enc-dec full attention
