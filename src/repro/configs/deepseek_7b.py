"""deepseek-7b [dense] — llama-arch [arXiv:2401.02954; hf].

30L d_model=4096 32H (GQA kv=32, i.e. MHA) d_ff=11008 vocab=102400.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b", family="dense",
    n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=11008, vocab=102400, rope_theta=1e4,
)

SMOKE = ModelConfig(
    name="deepseek-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=192, vocab=512,
)

SKIP_SHAPES = {"long_500k"}
