"""Fig. 7: portability — the interface fixes on other backends, plus
the beyond-paper stage 10 (MC-pipeline/PHY delay buffer, the paper's
future-work suggestion).
"""
from __future__ import annotations

from benchmarks.util import emit, run_sweep, write_csv
from repro.core import reference


def main(full: bool = False):
    out = {}
    for stage, name in (("07-prefetch", "ramulator"),
                        ("08-dramsim3", "dramsim3"),
                        ("09-ramulator2", "ramulator2"),
                        ("10-delay-buffer", "delay_buffer")):
        res, us = run_sweep(stage, full=full)
        write_csv(res, f"fig7_{name}")
        out[name] = res
        emit(f"fig7.{name}.unloaded_ns", us,
             f"{res.app_lat[0, 0]:.1f} (actual: {reference.UNLOADED_NS})")
        emit(f"fig7.{name}.saturation_gbs", us,
             f"{res.app_bw[0].max():.1f} "
             f"(actual: {reference.max_bandwidth_gbs(1.0):.0f})")
        emit(f"fig7.{name}.saturated_ns", us,
             f"{res.app_lat[0].max():.0f} (actual: 240-390; "
             f"paper: sims underpredict by up to 214)")
    return out


if __name__ == "__main__":
    main()
