"""Dense vs event-horizon weave engine: the tracked perf trajectory.

Times the *compiled* stage-10 Mess sweep under both weave engines
(``StageConfig.weave``) and records, per device preset:

* wall-clock per simulated window (compile excluded: the sweep runs
  twice and the second, steady-state run is reported);
* scan steps per window — the dense engine's ``ticks_per_window`` vs
  the event engine's static budget (`clocking.event_budget`), i.e. the
  *compiled* scan lengths that bound the work per window;
* per-pace evaluated events per window and budget-saturation counts
  (``weave_events`` / ``weave_sat`` views) — how much headroom the
  budget has before graceful degradation would kick in.

Artifact: ``reports/benchmarks/BENCH_weave.json`` — the first
benchmark artifact meant to be *diffed across PRs*, so weave-engine
regressions show up as numbers, not vibes.  The README perf table is
generated from it (``python -m benchmarks.weave_bench --readme``).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import OUT_DIR, emit
from repro.core import get_stage, sweep
from repro.core.platform import run_point

STAGE = "10-delay-buffer"
SMOKE = dict(windows=16, warmup=4, presets=("ddr4_2666",),
             paces=(2, 8, 24), mixes=(0,))
FULL = dict(windows=48, warmup=16,
            presets=("ddr4_2666", "ddr5_4800", "hbm2e"),
            paces=(1, 2, 4, 8, 12, 16, 24, 48, 64), mixes=(0, 16))

REPORT = os.path.join(OUT_DIR, "BENCH_weave.json")


def _time_sweep(cfg, paces, mixes):
    """Steady-state sweep wall-clock (second run; first compiles)."""
    sweep(cfg, paces=paces, write_mixes=mixes)
    t0 = time.perf_counter()
    sweep(cfg, paces=paces, write_mixes=mixes)
    return time.perf_counter() - t0


def _event_diag(cfg, paces):
    """Per-pace evaluated events/window, budget occupancy (events used
    over the static budget — the headroom before graceful degradation),
    and saturated windows (compiled).  Also fits a per-preset linear
    model ``events/window ~ per_pace * pace + fixed`` — the measured
    calibration `repro.core.mess.load_event_calibration` feeds into
    `event_covers` routing (ROADMAP "event-engine tuning")."""
    fn = jax.jit(jax.vmap(lambda p: run_point(cfg, p, jnp.int32(0))))
    out = jax.device_get(fn(jnp.asarray(paces, jnp.int32)))
    span = cfg.windows - cfg.warmup
    budget = cfg.event_budget()
    epw = [float(out["weave_events"][i]) / span for i in range(len(paces))]
    diag = {
        str(p): dict(
            events_per_window=round(epw[i], 1),
            budget_occupancy=round(epw[i] / budget, 3),
            sat_windows=int(out["weave_sat"][i]))
        for i, p in enumerate(paces)
    }
    # least-squares fit over the unsaturated points only (a saturated
    # window truncates its event count at the budget, biasing the rate)
    ok = [i for i, p in enumerate(paces) if not int(out["weave_sat"][i])]
    fit = None
    if len(ok) >= 2:
        a, b = np.polyfit([paces[i] for i in ok], [epw[i] for i in ok], 1)
        fit = dict(per_pace=round(float(a), 3), fixed=round(float(b), 1))
    return diag, fit


def bench_preset(preset: str, windows: int, warmup: int, paces, mixes):
    base = get_stage(STAGE, preset=preset, windows=windows, warmup=warmup)
    cfg_d = dataclasses.replace(base, weave="dense")
    cfg_e = dataclasses.replace(base, weave="event")
    clock = base.clock()
    n_windows = len(paces) * len(mixes) * windows

    wall_d = _time_sweep(cfg_d, paces, mixes)
    wall_e = _time_sweep(cfg_e, paces, mixes)
    pace_diag, rate_fit = _event_diag(cfg_e, paces)
    row = dict(
        ticks_per_window=clock.ticks_per_window_static,
        event_budget=base.event_budget(),
        step_reduction=round(
            clock.ticks_per_window_static / base.event_budget(), 2),
        dense_wall_s=round(wall_d, 3),
        event_wall_s=round(wall_e, 3),
        speedup=round(wall_d / wall_e, 2),
        us_per_window=dict(
            dense=round(wall_d / n_windows * 1e6, 1),
            event=round(wall_e / n_windows * 1e6, 1)),
        paces=pace_diag,
        event_rate_fit=rate_fit,
    )
    emit(f"weave.{preset}", wall_e / n_windows * 1e6,
         f"speedup={row['speedup']}x vs dense; "
         f"steps/window {base.event_budget()} vs "
         f"{clock.ticks_per_window_static} "
         f"({row['step_reduction']}x fewer)")
    return row


def main(full: bool = False, preset: str | None = None):
    knobs = dict(FULL if full else SMOKE)
    if preset:
        knobs["presets"] = (preset,)
    presets = {
        p: bench_preset(p, knobs["windows"], knobs["warmup"],
                        knobs["paces"], knobs["mixes"])
        for p in knobs["presets"]
    }
    report = dict(
        mode="full" if full else "smoke",
        stage=STAGE,
        windows=knobs["windows"],
        paces=list(knobs["paces"]),
        write_mixes=list(knobs["mixes"]),
        device=jax.devices()[0].platform,
        presets=presets,
    )
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(REPORT, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    return report


def readme_table(report: dict | None = None) -> str:
    """The README perf table, rendered from BENCH_weave.json."""
    if report is None:
        with open(REPORT) as f:
            report = json.load(f)
    lines = [
        "| preset | scan steps/window (dense → event) | compiled sweep "
        "wall-clock (dense → event) | speedup |",
        "|--------|------------------------------------|----------------"
        "------------------------|---------|",
    ]
    for name, row in report["presets"].items():
        lines.append(
            f"| `{name}` | {row['ticks_per_window']} → "
            f"{row['event_budget']} ({row['step_reduction']}× fewer) | "
            f"{row['dense_wall_s']} s → {row['event_wall_s']} s | "
            f"**{row['speedup']}×** |")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys

    if "--readme" in sys.argv:
        print(readme_table())
    else:
        main(full="--full" in sys.argv,
             preset=next((a.split("=", 1)[1] for a in sys.argv
                          if a.startswith("--preset=")), None))
