"""Fig. 2: baseline (DAMOV-native) three-view characterization.

Reproduces the paper's headline finding: the application view sits
flat at ~24 ns across the whole bandwidth range, decoupled from the
memory simulator's own statistics, while the interface view's
bandwidth exceeds the theoretical maximum.
"""
from __future__ import annotations

import numpy as np

from benchmarks.util import emit, run_sweep, write_csv
from repro.core import get_stage


def main(full: bool = False):
    res, us = run_sweep("01-baseline", full=full)
    write_csv(res, "fig2_baseline")
    peak = get_stage("01-baseline").platform.dram.peak_gbs

    app_flat = float(np.ptp(res.app_lat[0]))
    emit("fig2.app_latency_ns", us,
         f"{res.app_lat[0, 0]:.1f} (paper: 24; flat +/-{app_flat:.2f})")
    emit("fig2.sim_unloaded_ns", us,
         f"{res.sim_lat[0, 0]:.1f} (paper: 43)")
    emit("fig2.if_bw_over_theoretical", us,
         f"{res.if_bw.max() / peak:.2f}x (paper: 1.4x; >1 = bug visible)")
    emit("fig2.sim_saturation_gbs", us,
         f"{res.sim_bw.max():.1f} (paper: 100-120)")
    return res


if __name__ == "__main__":
    main()
