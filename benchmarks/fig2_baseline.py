"""Fig. 2: baseline (DAMOV-native) three-view characterization.

Reproduces the paper's headline finding: the application view sits
flat at ~24 ns across the whole bandwidth range, decoupled from the
memory simulator's own statistics, while the interface view's
bandwidth exceeds the theoretical maximum.

The decoupling is a property of the bound/weave interface, not of one
memory device — ``--preset ddr5_4800`` / ``--preset hbm2e`` rerun the
characterization on the other device presets and report the same
interface-inflation ratio plus each curve's deviation (MAPE) from that
preset's measured reference curve.
"""
from __future__ import annotations

import numpy as np

from benchmarks.util import emit, preset_suffix, run_sweep, write_csv
from repro.core import get_preset, reference
from repro.core.presets import PRESET_ORDER


def main(full: bool = False, preset: str = "ddr4_2666"):
    res, us = run_sweep("01-baseline", full=full, preset=preset)
    suffix = preset_suffix(preset)
    write_csv(res, f"fig2_baseline{suffix}")
    peak = get_preset(preset).peak_gbs

    app_flat = float(np.ptp(res.app_lat[0]))
    emit(f"fig2{suffix}.app_latency_ns", us,
         f"{res.app_lat[0, 0]:.1f} (paper: 24; flat +/-{app_flat:.2f})")
    emit(f"fig2{suffix}.sim_unloaded_ns", us,
         f"{res.sim_lat[0, 0]:.1f} (paper: 43)")
    emit(f"fig2{suffix}.if_bw_over_theoretical", us,
         f"{res.if_bw.max() / peak:.2f}x (paper: 1.4x; >1 = bug visible)")
    emit(f"fig2{suffix}.sim_saturation_gbs", us,
         f"{res.sim_bw.max():.1f} (reference: "
         f"{reference.max_bandwidth_gbs(1.0, preset):.0f})")

    # per-mix deviation of the simulator-view curve from the preset's
    # measured reference curve (the Mess-style validation number)
    errs = []
    for i in range(len(res.write_mixes)):
        rf = res.read_fraction(i)
        ref_lat = reference.latency_ns(res.sim_bw[i], rf, preset)
        errs.append(np.mean(np.abs(res.sim_lat[i] - ref_lat)
                            / np.maximum(ref_lat, 1e-9)) * 100.0)
    emit(f"fig2{suffix}.sim_curve_mape_pct", us,
         f"{float(np.mean(errs)):.1f} (vs {preset} reference curves)")
    return res


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--preset", default="ddr4_2666",
                    choices=list(PRESET_ORDER))
    args = ap.parse_args()
    main(full=args.full, preset=args.preset)
