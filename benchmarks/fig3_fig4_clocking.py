"""Fig. 3 + Fig. 4: the two clocking corrections.

Fig. 3 (stage 02): enabling clock scaling removes the >theoretical
bandwidth, but DAMOV's integer freqRatio rounding leaves the interface
~21% below the memory simulator (1.05 vs 1.333 GHz).
Fig. 4 (stage 03): the picosecond interface (Listing 1b) aligns the
interface and simulator views exactly.
"""
from __future__ import annotations

from benchmarks.util import emit, run_sweep, write_csv
from repro.core import get_stage


def main(full: bool = False):
    res3, us3 = run_sweep("02-clock-scale", full=full)
    write_csv(res3, "fig3_clock_scale")
    ratio3 = float((res3.if_bw / res3.sim_bw).mean())
    emit("fig3.if_over_sim_bw", us3,
         f"{ratio3:.4f} (expected 0.7875 = 1.05/1.333 GHz)")
    peak = get_stage("02-clock-scale").platform.dram.peak_gbs
    emit("fig3.if_bw_over_theoretical", us3,
         f"{res3.if_bw.max() / peak:.2f}x (must be <= 1)")

    res4, us4 = run_sweep("03-ps-clock", full=full)
    write_csv(res4, "fig4_ps_clock")
    ratio4 = float((res4.if_bw / res4.sim_bw).mean())
    emit("fig4.if_over_sim_bw", us4, f"{ratio4:.4f} (expected 1.0000)")
    emit("fig4.sim_saturation_gbs", us4,
         f"{res4.sim_bw.max():.1f} (matches actual: 100-120)")
    return res3, res4


if __name__ == "__main__":
    main()
