"""Fig. 5: PI-controlled immediate-response latency (Sec. 3.4).

The application view recouples to the memory simulator: unloaded app
latency rises from ~24 ns to the corrected value (paper: 67 ns, actual
HW: 89 ns), and the loaded app curve tracks the interface view.
"""
from __future__ import annotations

import numpy as np

from benchmarks.util import emit, run_sweep, write_csv


def main(full: bool = False):
    res, us = run_sweep("04-model-correct", full=full)
    write_csv(res, "fig5_model_correct")
    emit("fig5.app_unloaded_ns", us,
         f"{res.app_lat[0, 0]:.1f} (paper: 67; actual HW: 89)")
    # coupling: correlation between app and interface latency curves
    a, i = res.app_lat.ravel(), res.if_lat.ravel()
    corr = float(np.corrcoef(a, i)[0, 1])
    emit("fig5.app_if_correlation", us,
         f"{corr:.3f} (baseline: ~0 — decoupled)")
    emit("fig5.app_saturated_ns", us,
         f"{res.app_lat[0].max():.0f} (views now move together)")
    return res


if __name__ == "__main__":
    main()
