"""Application-perspective validation: per-app runtime MAPE by stage.

The paper's Table-style validation, built on `repro.traces`: replay the
DAMOV-style application suite through the stage progression and report,
per stage, each application's predicted runtime plus the MAPE against
the real-system anchors derived from the measured Mess curves.

Each stage is ONE batched compile: `jax.vmap` over the stacked
application axis (6 apps x all windows in a single XLA program).  The
expected narrative is the paper's: the baseline's decoupled application
view makes latency-bound apps (pointer_chase, bfs) run far too fast;
the interface corrections (stages 03-04) recouple them and the MAPE
drops monotonically.

CSV: ``reports/benchmarks/app_validation.csv`` with one row per
(stage, app): runtime, anchor, error, and the three latency views.
"""
from __future__ import annotations

import csv
import os
import time

import numpy as np

from benchmarks.util import OUT_DIR, emit
from repro.traces import (anchor_suite_ms, make_suite, mape, replay_stages,
                          stack_traces)

STAGES = ("01-baseline", "03-ps-clock", "04-model-correct",
          "07-prefetch", "10-delay-buffer")
FAST = dict(windows=32, warmup=8, n=2048)
FULL = dict(windows=96, warmup=24, n=8192)


def main(full: bool = False):
    knobs = FULL if full else FAST
    names, traces = make_suite(n=knobs["n"])
    batch = stack_traces(traces)
    anchors = anchor_suite_ms(traces)

    t0 = time.perf_counter()
    results = replay_stages(STAGES, batch, windows=knobs["windows"],
                            warmup=knobs["warmup"])
    wall = time.perf_counter() - t0
    us = wall / (len(STAGES) * len(names)) * 1e6

    rows = []
    for stage, out in results.items():
        err = mape(out["runtime_ms"], anchors)
        emit(f"app_validation.{stage}.mape_pct", us, f"{err:.1f}")
        for i, nm in enumerate(names):
            rows.append(dict(
                stage=stage, app=nm,
                runtime_ms=f"{out['runtime_ms'][i]:.5f}",
                anchor_ms=f"{anchors[i]:.5f}",
                err_pct=f"{100 * (out['runtime_ms'][i] / anchors[i] - 1):.1f}",
                sim_lat_ns=f"{out['sim_lat_ns'][i]:.1f}",
                if_lat_ns=f"{out['if_lat_ns'][i]:.1f}",
                app_lat_ns=f"{out['app_lat_ns'][i]:.1f}",
                sim_bw_gbs=f"{out['sim_bw_gbs'][i]:.1f}",
            ))

    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "app_validation.csv")
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)

    # headline: correction narrative — MAPE of first vs last stage
    first = mape(results[STAGES[0]]["runtime_ms"], anchors)
    last = mape(results[STAGES[-1]]["runtime_ms"], anchors)
    emit("app_validation.baseline_vs_corrected", us,
         f"{first:.1f} -> {last:.1f} (MAPE %, decoupling fixed)")
    return results


if __name__ == "__main__":
    import sys
    main(full="--full" in sys.argv)
