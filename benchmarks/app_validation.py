"""Application-perspective validation: per-app runtime MAPE by stage.

The paper's Table-style validation, built on `repro.traces`: replay the
DAMOV-style application suite through the stage progression and report,
per stage, each application's predicted runtime plus the MAPE against
the real-system anchors derived from the measured Mess curves — per
memory-device preset (DDR4-2666 / DDR5-4800 / HBM2e).

Each (preset, stage) cell is ONE compiled program whose application
axis is sharded across all devices (`repro.core.shard`): 6 apps x all
windows in a single XLA program, vmap fallback on one device.  The
expected narrative is the paper's: the baseline's decoupled application
view makes latency-bound apps (pointer_chase, bfs) run far too fast;
the interface corrections (stages 03-04) recouple them and the MAPE
drops monotonically — on every device generation, against that
generation's own anchors.

``--mix`` adds the multiprogrammed validation: three named per-core
trace mixes (`repro.traces.mix`) replayed as one batched compile per
(preset, stage), reporting each app's *in-mix* runtime and MAPE
against the joint-fixed-point mix anchors (`anchor_mix_ms`) next to
its solo runtime — the regime where interface contention actually
separates the three perspectives.  ``--sockets 2`` runs either mode on
the two-socket frontend (required to drive hbm2e past the ~200 GB/s
single-socket ceiling; see docs/VALIDATION.md).

CSV: ``reports/benchmarks/app_validation[_<preset>][_2s].csv`` with one
row per (stage, app) — and ``app_validation_mix[...]`` with one row per
(stage, mix, app).

Usage:
    python -m benchmarks.app_validation [--full] [--preset P] [--grid]
                                        [--mix] [--sockets N]
"""
from __future__ import annotations

import csv
import os
import time

from benchmarks.util import OUT_DIR, emit, preset_suffix
from repro.core import get_stage
from repro.core.presets import PRESET_ORDER
from repro.core.workload import N_CORES_PER_SOCKET
from repro.obs.telemetry import hist_percentiles
from repro.traces import (anchor_mix_ms, anchor_suite_ms, assign_traces,
                          make_suite, mape, replay_mixes, replay_stages,
                          replay_suite, split_cores, stack_mixes,
                          stack_traces)

STAGES = ("01-baseline", "03-ps-clock", "04-model-correct",
          "07-prefetch", "10-delay-buffer")
FAST = dict(windows=32, warmup=8, n=2048)
FULL = dict(windows=96, warmup=24, n=8192)

#: named multiprogrammed mixes (kernel names; traffic cores split
#: evenly across the apps of a mix by `split_cores`)
MIXES = (
    ("stream+chase", ("stream", "pointer_chase")),
    ("stream+gups", ("stream", "gups")),
    ("bfs+spmv+stencil", ("bfs_frontier", "spmv", "stencil3d")),
)
MIX_STAGES = ("01-baseline", "10-delay-buffer")


def _if_percentiles_ns(out, warmup: int, i: int):
    """p50/p95/p99 of the CPU-perceived read latency for one batch row.

    Reduced from the telemetry interface-view histogram
    (``tele_hist_if_ps``, the log2-bucketed per-read latencies behind
    ``sum_if_lat_ps``) — per-request percentiles next to the means the
    MAPE columns summarize; the groundwork for the ROADMAP
    LLM-serving per-request-percentile scenario.
    """
    hist = out["tele_hist_if_ps"][i, warmup:]          # (W', C, B)
    return hist_percentiles(hist) * 1e-3               # ps -> ns


def _suffix(preset: str, sockets: int) -> str:
    return preset_suffix(preset) + ("" if sockets == 1 else f"_{sockets}s")


def _write_csv(rows, name: str):
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.csv")
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)
    return path


def run_preset(preset: str, full: bool = False, stages=STAGES,
               sockets: int = 1):
    """Validate one device preset across the stage progression."""
    knobs = FULL if full else FAST
    names, traces = make_suite(n=knobs["n"])
    batch = stack_traces(traces)
    anchors = anchor_suite_ms(traces, preset, n_sockets=sockets)

    t0 = time.perf_counter()
    results = replay_stages(stages, batch, preset=preset,
                            windows=knobs["windows"],
                            warmup=knobs["warmup"], n_sockets=sockets,
                            telemetry=True)
    wall = time.perf_counter() - t0
    us = wall / (len(stages) * len(names)) * 1e6

    tag = f"app_validation{_suffix(preset, sockets)}"
    mtag = preset if sockets == 1 else f"{preset}_{sockets}s"
    rows = []
    for stage, out in results.items():
        err = mape(out["runtime_ms"], anchors)
        emit(f"app_validation.{mtag}.{stage}.mape_pct", us, f"{err:.1f}")
        for i, nm in enumerate(names):
            p50, p95, p99 = _if_percentiles_ns(out, knobs["warmup"], i)
            rows.append(dict(
                preset=preset, stage=stage, app=nm, sockets=sockets,
                runtime_ms=f"{out['runtime_ms'][i]:.5f}",
                anchor_ms=f"{anchors[i]:.5f}",
                err_pct=f"{100 * (out['runtime_ms'][i] / anchors[i] - 1):.1f}",
                sim_lat_ns=f"{out['sim_lat_ns'][i]:.1f}",
                if_lat_ns=f"{out['if_lat_ns'][i]:.1f}",
                if_p50_ns=f"{p50:.1f}", if_p95_ns=f"{p95:.1f}",
                if_p99_ns=f"{p99:.1f}",
                app_lat_ns=f"{out['app_lat_ns'][i]:.1f}",
                sim_bw_gbs=f"{out['sim_bw_gbs'][i]:.1f}",
            ))
    _write_csv(rows, tag)

    # headline: correction narrative — MAPE of first vs last stage
    first = mape(results[stages[0]]["runtime_ms"], anchors)
    last = mape(results[stages[-1]]["runtime_ms"], anchors)
    emit(f"app_validation.{mtag}.baseline_vs_corrected", us,
         f"{first:.1f} -> {last:.1f} (MAPE %, decoupling fixed)")
    return results


def run_mixes(preset: str, full: bool = False, stages=MIX_STAGES,
              sockets: int = 1):
    """Multiprogrammed validation: per-app-in-mix runtime MAPE.

    All mixes of `MIXES` are stacked into ONE batched compile per
    (preset, stage) — the mix axis is the sharded batch axis — and each
    app's in-mix runtime is reported next to its solo runtime from the
    same stage.
    """
    knobs = FULL if full else FAST
    n_cores = N_CORES_PER_SOCKET * sockets

    built = []          # (mix_name, app_names, traces, cores_per_app)
    for mix_name, kernels in MIXES:
        names, traces = make_suite(n=knobs["n"], names=kernels)
        asn = split_cores(len(traces), n_cores)
        cores = [asn.count(a) for a in range(len(traces))]
        built.append((mix_name, names, traces, cores,
                      assign_traces(traces, asn)))
    mix_batch = stack_mixes([b[4] for b in built])

    # solo baselines (one compile per stage, shared by every mix);
    # only the kernels that actually appear in a mix are replayed
    used = tuple(dict.fromkeys(k for _, ks in MIXES for k in ks))
    solo_names, solo_traces = make_suite(n=knobs["n"], names=used)
    solo_anchor = dict(zip(solo_names, anchor_suite_ms(
        solo_traces, preset, n_sockets=sockets)))

    mtag = preset if sockets == 1 else f"{preset}_{sockets}s"
    rows, results = [], {}
    for stage in stages:
        cfg = get_stage(stage, preset=preset, windows=knobs["windows"],
                        warmup=knobs["warmup"], n_sockets=sockets,
                        telemetry=True)
        t0 = time.perf_counter()
        out = replay_mixes(cfg, mix_batch)
        solo = replay_suite(cfg, stack_traces(solo_traces))
        us = (time.perf_counter() - t0) / len(built) * 1e6
        solo_rt = dict(zip(solo_names, solo["runtime_ms"]))
        results[stage] = out

        for m, (mix_name, names, traces, cores, _) in enumerate(built):
            anchors = anchor_mix_ms(traces, cores, preset,
                                    n_sockets=sockets)
            pred = out["app_runtime_ms"][m, :len(names)]
            err = mape(pred, anchors)
            emit(f"app_mix.{mtag}.{stage}.{mix_name}.mape_pct",
                 us, f"{err:.1f}")
            p50, p95, p99 = _if_percentiles_ns(out, knobs["warmup"], m)
            for a, nm in enumerate(names):
                rows.append(dict(
                    preset=preset, stage=stage, mix=mix_name, app=nm,
                    sockets=sockets, cores=cores[a],
                    runtime_ms=f"{pred[a]:.5f}",
                    anchor_ms=f"{anchors[a]:.5f}",
                    err_pct=f"{100 * (pred[a] / anchors[a] - 1):.1f}",
                    solo_runtime_ms=f"{solo_rt[nm]:.5f}",
                    solo_anchor_ms=f"{solo_anchor[nm]:.5f}",
                    mix_bw_gbs=f"{out['sim_bw_gbs'][m]:.1f}",
                    mix_if_p50_ns=f"{p50:.1f}", mix_if_p95_ns=f"{p95:.1f}",
                    mix_if_p99_ns=f"{p99:.1f}",
                ))
    _write_csv(rows, f"app_validation_mix{_suffix(preset, sockets)}")
    return results


def main(full: bool = False, preset: str = "ddr4_2666", grid: bool = False,
         mix: bool = False, sockets: int = 1):
    presets = PRESET_ORDER if grid else (preset,)
    if mix:
        return {p: run_mixes(p, full=full, sockets=sockets)
                for p in presets}
    return {p: run_preset(p, full=full, sockets=sockets) for p in presets}


def main_mix(full: bool = False, **kw):
    """Registry entry point for the multiprogrammed-mix benchmark."""
    kw.setdefault("grid", True)
    return main(full=full, mix=True, **kw)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--preset", default="ddr4_2666",
                    choices=list(PRESET_ORDER))
    ap.add_argument("--grid", action="store_true",
                    help="run the full preset x stage x app grid")
    ap.add_argument("--mix", action="store_true",
                    help="multiprogrammed per-core trace mixes "
                         "(per-app-in-mix MAPE next to solo numbers)")
    ap.add_argument("--sockets", type=int, default=1, choices=(1, 2),
                    help="traffic sockets (2 doubles the frontend "
                         "issue capacity — needed to saturate hbm2e)")
    args = ap.parse_args()
    main(full=args.full, preset=args.preset, grid=args.grid,
         mix=args.mix, sockets=args.sockets)
