"""Application-perspective validation: per-app runtime MAPE by stage.

The paper's Table-style validation, built on `repro.traces`: replay the
DAMOV-style application suite through the stage progression and report,
per stage, each application's predicted runtime plus the MAPE against
the real-system anchors derived from the measured Mess curves — per
memory-device preset (DDR4-2666 / DDR5-4800 / HBM2e).

Each (preset, stage) cell is ONE compiled program whose application
axis is sharded across all devices (`repro.core.shard`): 6 apps x all
windows in a single XLA program, vmap fallback on one device.  The
expected narrative is the paper's: the baseline's decoupled application
view makes latency-bound apps (pointer_chase, bfs) run far too fast;
the interface corrections (stages 03-04) recouple them and the MAPE
drops monotonically — on every device generation, against that
generation's own anchors.

CSV: ``reports/benchmarks/app_validation[_<preset>].csv`` with one row
per (stage, app): runtime, anchor, error, and the three latency views.

Usage:
    python -m benchmarks.app_validation [--full] [--preset P] [--grid]
"""
from __future__ import annotations

import csv
import os
import time

from benchmarks.util import OUT_DIR, emit, preset_suffix
from repro.core.presets import PRESET_ORDER
from repro.traces import (anchor_suite_ms, make_suite, mape, replay_stages,
                          stack_traces)

STAGES = ("01-baseline", "03-ps-clock", "04-model-correct",
          "07-prefetch", "10-delay-buffer")
FAST = dict(windows=32, warmup=8, n=2048)
FULL = dict(windows=96, warmup=24, n=8192)


def _write_csv(rows, preset: str):
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR,
                        f"app_validation{preset_suffix(preset)}.csv")
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)
    return path


def run_preset(preset: str, full: bool = False, stages=STAGES):
    """Validate one device preset across the stage progression."""
    knobs = FULL if full else FAST
    names, traces = make_suite(n=knobs["n"])
    batch = stack_traces(traces)
    anchors = anchor_suite_ms(traces, preset)

    t0 = time.perf_counter()
    results = replay_stages(stages, batch, preset=preset,
                            windows=knobs["windows"],
                            warmup=knobs["warmup"])
    wall = time.perf_counter() - t0
    us = wall / (len(stages) * len(names)) * 1e6

    rows = []
    for stage, out in results.items():
        err = mape(out["runtime_ms"], anchors)
        emit(f"app_validation.{preset}.{stage}.mape_pct", us, f"{err:.1f}")
        for i, nm in enumerate(names):
            rows.append(dict(
                preset=preset, stage=stage, app=nm,
                runtime_ms=f"{out['runtime_ms'][i]:.5f}",
                anchor_ms=f"{anchors[i]:.5f}",
                err_pct=f"{100 * (out['runtime_ms'][i] / anchors[i] - 1):.1f}",
                sim_lat_ns=f"{out['sim_lat_ns'][i]:.1f}",
                if_lat_ns=f"{out['if_lat_ns'][i]:.1f}",
                app_lat_ns=f"{out['app_lat_ns'][i]:.1f}",
                sim_bw_gbs=f"{out['sim_bw_gbs'][i]:.1f}",
            ))
    _write_csv(rows, preset)

    # headline: correction narrative — MAPE of first vs last stage
    first = mape(results[stages[0]]["runtime_ms"], anchors)
    last = mape(results[stages[-1]]["runtime_ms"], anchors)
    emit(f"app_validation.{preset}.baseline_vs_corrected", us,
         f"{first:.1f} -> {last:.1f} (MAPE %, decoupling fixed)")
    return results


def main(full: bool = False, preset: str = "ddr4_2666", grid: bool = False):
    presets = PRESET_ORDER if grid else (preset,)
    return {p: run_preset(p, full=full) for p in presets}


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--preset", default="ddr4_2666",
                    choices=list(PRESET_ORDER))
    ap.add_argument("--grid", action="store_true",
                    help="run the full preset x stage x app grid")
    args = ap.parse_args()
    main(full=args.full, preset=args.preset, grid=args.grid)
