"""Roofline bench: summarize the dry-run records (§Roofline source).

Reads reports/dryrun/<mesh>/*.json (produced by
``python -m repro.launch.dryrun --all --mesh both``) and emits the
per-cell roofline terms.  Does NOT recompile — the dry-run is the
expensive step and is cached.
"""
from __future__ import annotations

from benchmarks.util import emit
from repro.perfmodel.report import load_records


def main(full: bool = False):
    for mesh in ("pod", "multipod"):
        recs = load_records(mesh=mesh)
        if not recs:
            emit(f"roofline.{mesh}", 0.0,
                 "NO RECORDS — run python -m repro.launch.dryrun --all")
            continue
        for r in recs:
            dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
            emit(f"roofline.{mesh}.{r['arch']}.{r['shape']}",
                 r["compile_s"] * 1e6,
                 f"bound={r['bottleneck']} "
                 f"compute={r['compute_s'] * 1e3:.1f}ms "
                 f"memory={r['memory_s'] * 1e3:.1f}ms "
                 f"collective={r['collective_s'] * 1e3:.1f}ms "
                 f"useful={r['useful_ratio']:.2f} "
                 f"frac={r['compute_s'] / dom if dom else 0:.3f} "
                 f"GiB/dev={r['bytes_per_device'] / 2 ** 30:.2f}")


if __name__ == "__main__":
    main()
