"""Kernel micro-benchmarks (interpret-mode correctness + host timing).

Wall times here are CPU interpret-mode numbers — NOT TPU performance;
the derived column reports the correctness deltas vs the oracles and
the arithmetic-intensity characteristics that matter on the target.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import emit


def bench_flash_attention():
    from repro.kernels.flash_attention import flash_attention, mha_reference
    rng = np.random.default_rng(0)
    b, hq, hkv, s, d = 1, 8, 2, 512, 64
    q = jnp.asarray(rng.standard_normal((b, hq, s, d)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.bfloat16)
    o = flash_attention(q, k, v, causal=True)
    t0 = time.perf_counter()
    o = flash_attention(q, k, v, causal=True).block_until_ready()
    us = (time.perf_counter() - t0) * 1e6
    r = mha_reference(q, k, v, causal=True)
    err = float(jnp.max(jnp.abs(o.astype(jnp.float32)
                                - r.astype(jnp.float32))))
    flops = 4 * b * hq * s * s * d
    emit("kernel.flash_attention", us,
         f"maxerr={err:.1e} vs oracle; {flops / 1e9:.2f} GFLOP tile-case")


def bench_bank_timing():
    from repro.kernels.bank_timing import (frfcfs_select, pack_scalars,
                                           scalars_tuple, select_reference)
    rng = np.random.default_rng(1)
    C, Q = 6, 256
    r = lambda hi, shape=(C, Q): jnp.asarray(
        rng.integers(0, hi, size=shape, dtype=np.int32))
    args = [r(2), r(2), r(8), r(8) - 1, r(100), r(100), r(100), r(100),
            r(2), r(2), r(1000)]
    ch = pack_scalars(jnp.int32(50), r(100, (C,)), r(100, (C,)),
                      r(100, (C,)), r(2, (C,)), r(8, (C,)))
    sel, cmd = frfcfs_select(*args, ch)
    t0 = time.perf_counter()
    sel, cmd = frfcfs_select(*args, ch)
    jax.block_until_ready((sel, cmd))
    us = (time.perf_counter() - t0) * 1e6
    sr, cr = select_reference(*args, scalars_tuple(ch))
    ok = bool((np.asarray(cmd) == np.asarray(cr)).all())
    emit("kernel.bank_timing_select", us,
         f"match={ok}; {C}x{Q} eligibility plane per DRAM tick")


def bench_addr_decode():
    from repro.kernels.addr_decode import decode_skylake, decode_reference
    rng = np.random.default_rng(2)
    lines = jnp.asarray(rng.integers(0, 2 ** 32, 1 << 16, dtype=np.uint32))
    d = decode_skylake(lines)
    t0 = time.perf_counter()
    d = decode_skylake(lines)
    jax.block_until_ready(d.channel)
    us = (time.perf_counter() - t0) * 1e6
    r = decode_reference(lines)
    ok = all(bool((np.asarray(getattr(d, f))
                   == np.asarray(getattr(r, f))).all()) for f in d._fields)
    emit("kernel.addr_decode", us,
         f"match={ok}; 64k lines/call, 4B/line packed output")


def main(full: bool = False):
    bench_flash_attention()
    bench_bank_timing()
    bench_addr_decode()


if __name__ == "__main__":
    main()
