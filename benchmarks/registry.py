"""One registry of benchmark entry points.

Every benchmark module exposes ``main(full: bool = False, **kw)`` and
writes its artifacts under ``reports/benchmarks/``.  This registry is
the single source of truth consumed by:

* ``benchmarks/run.py``      — runs benchmarks by name (``--only``),
* ``scripts/reanalyze.py``   — lists benchmarks + their report globs,
* docs                       — the table in docs/ARCHITECTURE.md.

Adding a benchmark = adding one `BenchSpec` entry here (PR 1 bolted
``app_validation`` onto the run.py dict by hand; don't repeat that).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable


@dataclasses.dataclass(frozen=True)
class BenchSpec:
    """One benchmark entry point.

    ``module`` is imported lazily (JAX-heavy imports stay off the
    registry import path); ``reports`` are the CSV/JSON artifact globs
    the benchmark writes under ``reports/benchmarks/``.
    """

    name: str
    module: str                       # dotted module with main(full=...)
    description: str
    reports: tuple = ()               # artifact globs under reports/
    main_attr: str = "main"           # entry point inside the module

    @property
    def main(self) -> Callable:
        return getattr(importlib.import_module(self.module),
                       self.main_attr)


#: sorted by name so `run.py --list` / `reanalyze --list-benchmarks`
#: print a stable alphabetized listing (tested in tests/test_docs.py)
BENCHMARKS: dict[str, BenchSpec] = {s.name: s for s in sorted((
    BenchSpec("cmd_oracle", "benchmarks.cmd_oracle",
              "command-level differential oracle: dense vs event "
              "cmd_trace streams identical + JEDEC-legal across the "
              "preset x stage x app grid",
              ("cmd_oracle*.json", "cmd_oracle*.cmd.trace")),
    BenchSpec("fig2", "benchmarks.fig2_baseline",
              "baseline three-view characterization (per preset)",
              ("fig2_baseline*.csv",)),
    BenchSpec("fig3_fig4", "benchmarks.fig3_fig4_clocking",
              "clock-scaling progression (Fig. 3/4)",
              ("fig3*.csv", "fig4*.csv")),
    BenchSpec("fig5", "benchmarks.fig5_model_correct",
              "PI-controlled immediate response (Fig. 5)",
              ("fig5*.csv",)),
    BenchSpec("fig6", "benchmarks.fig6_enhancements",
              "addrmap / NOC / prefetch enhancements (Fig. 6)",
              ("fig6*.csv",)),
    BenchSpec("fig7", "benchmarks.fig7_portability",
              "backend-flavor portability (Fig. 7)",
              ("fig7*.csv",)),
    BenchSpec("kernels", "benchmarks.kernels_bench",
              "Pallas kernel micro-benchmarks",
              ()),
    BenchSpec("roofline", "benchmarks.roofline_bench",
              "HLO roofline model benchmarks",
              ()),
    BenchSpec("serving", "benchmarks.serving",
              "LLM-serving traffic on the memory platform: model x "
              "preset x arrival-rate grid, per-request latency and "
              "interface p50/p95/p99 under contention",
              ("BENCH_serve.json",)),
    BenchSpec("app_validation", "benchmarks.app_validation",
              "per-app runtime MAPE vs per-preset anchors "
              "(--preset / --grid / --sockets)",
              ("app_validation.csv", "app_validation_[0-9]s.csv",
               "app_validation_ddr5*.csv", "app_validation_hbm2e*.csv")),
    BenchSpec("app_mix", "benchmarks.app_validation",
              "multiprogrammed per-core trace mixes: per-app-in-mix "
              "runtime MAPE next to solo numbers (--mix mode)",
              ("app_validation_mix*.csv",), main_attr="main_mix"),
    BenchSpec("perspectives", "benchmarks.perspectives",
              "three-perspective divergence ladder: per-window rank "
              "correlation of sim/if/app views across stages 01->10, "
              "plus a Perfetto timeline of the final stage",
              ("perspectives*.json",)),
    BenchSpec("weave", "benchmarks.weave_bench",
              "dense vs event-horizon weave engine: compiled sweep "
              "wall-clock, scan steps/window, event-budget headroom",
              ("BENCH_weave.json",)),
), key=lambda s: s.name)}


def get_benchmark(name: str) -> BenchSpec:
    try:
        return BENCHMARKS[name]
    except KeyError:
        raise ValueError(
            f"unknown benchmark {name!r}; one of {list(BENCHMARKS)}"
        ) from None
