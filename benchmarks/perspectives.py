"""The three-perspective divergence report across the correction ladder.

For every stage 01→10 this benchmark replays one multiprogrammed mix
(STREAM + GUPS — one bandwidth-bound app, one latency-bound) with
telemetry on, collects the per-window latency series each perspective
reports, and rank-correlates them (`repro.obs.perspectives`).  The
artifact is the paper's narrative as numbers: in the broken stages the
application view is *constant* (rho ~ 0 — decoupled from whatever the
memory system does); the stage-04 PI correction feeds weave-phase
latency back into the bound phase and the correlation jumps toward 1,
staying re-coupled through the backend-flavor stages.

Artifacts (``reports/benchmarks/``):

* ``perspectives_<preset>.json`` — the divergence ladder
  (`repro.obs.perspectives.divergence_report`) plus per-stage summary
  statistics (`repro.obs.telemetry.summarize`);
* ``perspectives_<preset>_trace.json`` — a Perfetto / Chrome-trace
  timeline of the final stage's run (open at https://ui.perfetto.dev),
  schema-checked by `repro.obs.export.validate_perfetto`.
"""
from __future__ import annotations

import json
import os

import jax

from benchmarks.util import OUT_DIR, emit, preset_suffix
from repro import obs
from repro.core import get_stage
from repro.core.platform import run_frontend
from repro.obs.perspectives import divergence_report
from repro.traces import assign_traces, split_cores
from repro.traces.frontend import TraceFrontend
from repro.traces.kernels import gups, stream

#: the correction ladder (00 is the native DAMOV reference, not a
#: correction step — the report starts at the reproduced baseline)
LADDER = ("01-baseline", "02-clock-scale", "03-ps-clock",
          "04-model-correct", "05-addrmap", "06-noc", "07-prefetch",
          "08-dramsim3", "09-ramulator2", "10-delay-buffer")

#: long enough that no core's trace completes inside the run (a
#: finished core's constant cursor would fake an app-view flatline)
SMOKE = dict(windows=24, warmup=8, n=1 << 14)
FULL = dict(windows=96, warmup=32, n=1 << 17)


def run_stage(stage: str, preset: str, windows: int, warmup: int, n: int):
    """One telemetry-on mix replay; returns the collected record."""
    cfg = get_stage(stage, preset=preset, windows=windows, warmup=warmup,
                    telemetry=True)
    wcfg = cfg.workload_config()
    mix = assign_traces([stream(n=n), gups(n=n)],
                        split_cores(2, wcfg.n_cores), phase_offsets=None)
    fe = TraceFrontend(mix, wcfg)
    views, outs = jax.device_get(
        jax.jit(lambda: run_frontend(cfg, fe))())
    return obs.collect(cfg, views, outs)


def main(full: bool = False, preset: str = "ddr4_2666"):
    knobs = FULL if full else SMOKE
    records = {}
    for stage in LADDER:
        records[stage] = run_stage(stage, preset, **knobs)
    report = divergence_report(records)
    report.update(mode="full" if full else "smoke", preset=preset,
                  **{k: knobs[k] for k in ("windows", "warmup", "n")},
                  summaries={s: obs.summarize(r)
                             for s, r in records.items()})

    os.makedirs(OUT_DIR, exist_ok=True)
    sfx = preset_suffix(preset)
    path = os.path.join(OUT_DIR, f"perspectives{sfx}.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")

    # the final stage's timeline, schema-checked — the CI smoke gate
    trace_path = os.path.join(OUT_DIR, f"perspectives{sfx}_trace.json")
    trace = obs.to_perfetto(records[LADDER[-1]], path=trace_path)
    obs.validate_perfetto(trace)

    row = report["ladder"][-1]
    emit(f"perspectives{sfx}", 0.0,
         f"rho_sim_app {report['ladder'][0]['rho_sim_app']:.2f} -> "
         f"{row['rho_sim_app']:.2f} across {len(LADDER)} stages; "
         f"monotone_ok={report['monotone_ok']}")
    return report


def ladder_table(report: dict | None = None,
                 preset: str = "ddr4_2666") -> str:
    """Render a saved divergence report as a markdown ladder table."""
    if report is None:
        sfx = preset_suffix(preset)
        with open(os.path.join(OUT_DIR, f"perspectives{sfx}.json")) as f:
            report = json.load(f)
    lines = ["| stage | rho(sim,app) | rho(sim,if) | rho(if,app) | "
             "sim lat ns | app lat ns |",
             "|-------|--------------|-------------|-------------|"
             "------------|------------|"]
    for row in report["ladder"]:
        lines.append(
            f"| {row['stage']} | {row['rho_sim_app']:+.3f} | "
            f"{row['rho_sim_if']:+.3f} | {row['rho_if_app']:+.3f} | "
            f"{row['sim_lat_ns_mean']:.1f} | {row['app_lat_ns_mean']:.1f} |")
    lines.append(f"\nmonotone_ok={report['monotone_ok']} "
                 f"end_to_end_gain={report['end_to_end_gain']} "
                 f"exceptions={report['exceptions']}")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys

    if "--table" in sys.argv:
        print(ladder_table(preset=next(
            (a.split("=", 1)[1] for a in sys.argv
             if a.startswith("--preset=")), "ddr4_2666")))
    else:
        main(full="--full" in sys.argv,
             preset=next((a.split("=", 1)[1] for a in sys.argv
                          if a.startswith("--preset=")), "ddr4_2666"))
