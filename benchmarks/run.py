"""Benchmark aggregator: one section per paper figure/table.

Prints ``name,us_per_call,derived`` CSV rows.  ``--full`` runs the
paper-resolution sweeps (14 paces x 5 mixes, 96 windows); the default
is CI-speed (6 paces x 3 mixes, 48 windows).
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names (fig2,...)")
    args = ap.parse_args()

    from benchmarks import (app_validation, fig2_baseline,
                            fig3_fig4_clocking, fig5_model_correct,
                            fig6_enhancements, fig7_portability,
                            kernels_bench, roofline_bench)
    benches = {
        "fig2": fig2_baseline.main,
        "fig3_fig4": fig3_fig4_clocking.main,
        "fig5": fig5_model_correct.main,
        "fig6": fig6_enhancements.main,
        "fig7": fig7_portability.main,
        "kernels": kernels_bench.main,
        "roofline": roofline_bench.main,
        "app_validation": app_validation.main,
    }
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    t0 = time.time()
    for name, fn in benches.items():
        if only and name not in only:
            continue
        print(f"# --- {name} ---", file=sys.stderr)
        fn(full=args.full)
    print(f"# total {time.time() - t0:.0f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
