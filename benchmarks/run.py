"""Benchmark aggregator: one section per paper figure/table.

Prints ``name,us_per_call,derived`` CSV rows.  ``--full`` runs the
paper-resolution sweeps (14 paces x 5 mixes, 96 windows); the default
is CI-speed (6 paces x 3 mixes, 48 windows).  The benchmark set comes
from the single registry in `benchmarks.registry` (``--list`` shows
it); ``--preset`` forwards a memory-device preset to the benchmarks
that accept one (fig2, app_validation).
"""
from __future__ import annotations

import argparse
import inspect
import sys
import time

from benchmarks.registry import BENCHMARKS, get_benchmark


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names (fig2,...)")
    ap.add_argument("--preset", default=None,
                    help="device preset for preset-aware benchmarks")
    ap.add_argument("--list", action="store_true",
                    help="list registered benchmarks and exit")
    args = ap.parse_args()

    if args.list:
        for spec in BENCHMARKS.values():
            print(f"{spec.name:16s} {spec.description}")
        return

    names = args.only.split(",") if args.only else list(BENCHMARKS)
    specs = [get_benchmark(n) for n in names]
    print("name,us_per_call,derived")
    t0 = time.time()
    for spec in specs:
        print(f"# --- {spec.name} ---", file=sys.stderr)
        kw = {}
        if args.preset and "preset" in inspect.signature(
                spec.main).parameters:
            kw["preset"] = args.preset
        spec.main(full=args.full, **kw)
    print(f"# total {time.time() - t0:.0f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
