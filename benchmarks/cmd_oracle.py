"""Command-level differential oracle: both engines, every DRAM rule.

The tentpole fidelity claim is layered: windows agree (golden grid),
telemetry planes agree (obs), and — this harness — the *per-command
schedule* agrees and is JEDEC-legal.  For a grid of preset x stage x
app cells it replays the same workload through the dense and the
event-horizon weave engines with ``StageConfig(cmd_trace=True)``,
flattens both recorded streams (`repro.oracle.extract_stream`), and
asserts:

* **stream equality** — `repro.oracle.diff_streams` finds no
  divergence between the engines, row for row;
* **protocol legality** — `repro.oracle.check_stream` replays the
  stream against the preset's `DramParams` and every timing/state
  rule in `repro.oracle.RULES` holds, refresh deadlines included;
* **stats agreement** — per-channel bandwidth and command mixes
  (`repro.oracle.stream_stats`) match between engines.

The DDR4 cells run enough windows to cross ``tREFI`` so the all-bank
refresh path is exercised; DDR5 fires per-bank refreshes (REFsb)
within a handful of windows.

Artifacts (``reports/benchmarks/``):

* ``cmd_oracle.json`` — per-cell legality + agreement report;
* ``cmd_oracle_ddr4_2666.cmd.trace`` — one exported Ramulator2-style
  command trace, schema-checked by `repro.obs.export.validate_cmd_trace`.
"""
from __future__ import annotations

import dataclasses
import json
import os

import jax
import numpy as np

from benchmarks.util import OUT_DIR, emit
from repro.core import get_stage
from repro.core.platform import run_frontend
from repro.core.workload import MessFrontend
from repro.obs.export import to_cmd_trace, validate_cmd_trace
from repro.oracle import check_stream, diff_streams, extract_stream, \
    stream_stats
from repro.traces import assign_traces, split_cores
from repro.traces.frontend import TraceFrontend
from repro.traces.kernels import gups, stream


def mess(pace, wr):
    def build(cfg):
        fe = MessFrontend(pace, wr, cfg.workload_config())
        return lambda: run_frontend(cfg, fe)

    build.app = f"mess-p{pace}w{wr}"
    return build


def solo(n):
    trace = stream(n=n)

    def build(cfg):
        return lambda: run_frontend(
            cfg, TraceFrontend(trace, cfg.workload_config()))

    build.app = "solo-stream"
    build.full_budget = True
    return build


def mix(n):
    apps = [stream(n=n), gups(n=n)]

    def build(cfg):
        m = assign_traces(apps,
                          split_cores(2, cfg.workload_config().n_cores),
                          phase_offsets=None)
        return lambda: run_frontend(cfg, TraceFrontend(m, cfg.workload_config()))

    build.app = "mix-stream-gups"
    build.full_budget = True
    return build


#: (stage, preset, app builder, windows) — windows chosen so every
#: preset crosses its refresh interval at least once (DDR4's
#: tREFI=10400 ticks needs ~17 windows of ~635 ticks; HBM2e ~9;
#: DDR5's per-bank tREFI=292 fires within the first window).
SMOKE = [
    ("01-baseline", "ddr4_2666", mess(8, 16), 20),
    ("10-delay-buffer", "ddr4_2666", mix(192), 20),
    ("04-model-correct", "ddr5_4800", solo(256), 6),
    ("09-ramulator2", "ddr5_4800", mess(8, 32), 6),
    ("04-model-correct", "hbm2e", mix(192), 12),
    ("10-delay-buffer", "hbm2e", mess(16, 0), 12),
]
FULL = SMOKE + [
    ("02-clock-scale", "ddr4_2666", solo(512), 24),
    ("05-addrmap", "ddr4_2666", mess(4, 0), 24),
    ("08-dramsim3", "ddr5_4800", mix(256), 12),
    ("09-ramulator2", "hbm2e", solo(512), 16),
]


def run_cell(stage, preset, frontend, windows):
    """One preset x stage x app cell: record on both engines, check."""
    streams, views = {}, {}
    for weave in ("dense", "event"):
        cfg = get_stage(stage, preset=preset, windows=windows,
                        warmup=max(windows // 5, 1), weave=weave,
                        cmd_trace=True)
        if weave == "event" and getattr(frontend, "full_budget", False):
            cfg = dataclasses.replace(
                cfg, weave_events=cfg.clock().ticks_per_window_static)
        v, _ = jax.device_get(jax.jit(frontend(cfg))())
        views[weave] = v
        streams[weave] = extract_stream(v, cfg.platform.dram)
    end_tick = int(cfg.clock().window_end_tick(cfg.windows - 1))

    diff = diff_streams(streams["dense"], streams["event"])
    rep = check_stream(streams["dense"], end_tick=end_tick)
    stats = {w: stream_stats(s, span_ticks=end_tick)
             for w, s in streams.items()}
    bw_delta = float(np.max(np.abs(stats["dense"]["bw_gbs"]
                                   - stats["event"]["bw_gbs"])))
    mix_agree = all(
        (stats["dense"][k] == stats["event"][k]).all()
        for k in ("RD", "WR", "ACT", "PRE", "REF"))
    sat = sum(int(np.sum(v["weave_sat"])) for v in views.values())
    cell = dict(
        stage=stage, preset=preset, app=frontend.app, windows=windows,
        end_tick=end_tick, n_commands=len(streams["dense"]),
        counts=streams["dense"].counts(), n_checked=rep.n_checked,
        violation_counts=rep.violation_counts,
        streams_identical=diff is None, diff=diff,
        legal_ok=rep.ok, mix_agree=bool(mix_agree),
        bw_delta_gbs=bw_delta, weave_sat=sat,
        bw_gbs=[round(float(x), 3)
                for x in stats["dense"]["bw_gbs"]],
        ok=bool(diff is None and rep.ok and mix_agree
                and bw_delta == 0.0 and sat == 0))
    return cell, streams["dense"]


def main(full: bool = False):
    cells, export_stream = [], None
    for stage, preset, frontend, windows in (FULL if full else SMOKE):
        cell, s = run_cell(stage, preset, frontend, windows)
        cells.append(cell)
        if preset == "ddr4_2666" and export_stream is None:
            export_stream = (s, preset)
        status = "ok" if cell["ok"] else "FAIL"
        emit(f"cmd_oracle/{preset}/{stage}/{cell['app']}", 0.0,
             f"{status} cmds={cell['n_commands']} "
             f"checked={sum(cell['n_checked'].values())} "
             f"ref={cell['counts']['REF']}")

    report = dict(schema="repro.oracle/cmd-oracle-v1",
                  mode="full" if full else "smoke",
                  all_ok=all(c["ok"] for c in cells), cells=cells)
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, "cmd_oracle.json"), "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")

    # one exported Ramulator2-style trace, schema-gated like the
    # Perfetto artifact in benchmarks/perspectives.py
    s, preset = export_stream
    path = os.path.join(OUT_DIR, f"cmd_oracle_{preset}.cmd.trace")
    validate_cmd_trace(to_cmd_trace(s, path=path, preset=preset))

    emit("cmd_oracle", 0.0,
         f"all_ok={report['all_ok']} cells={len(cells)} "
         f"exported={os.path.basename(path)}")
    if not report["all_ok"]:
        raise SystemExit("cmd_oracle: a grid cell failed "
                         "(see reports/benchmarks/cmd_oracle.json)")
    return report


def oracle_table(report: dict | None = None) -> str:
    """Render a saved cmd_oracle report as a markdown grid table."""
    if report is None:
        with open(os.path.join(OUT_DIR, "cmd_oracle.json")) as f:
            report = json.load(f)
    lines = ["| stage | preset | app | cmds | checked | REF | "
             "identical | legal |",
             "|-------|--------|-----|------|---------|-----|"
             "-----------|-------|"]
    for c in report["cells"]:
        lines.append(
            f"| {c['stage']} | {c['preset']} | {c['app']} | "
            f"{c['n_commands']} | {sum(c['n_checked'].values())} | "
            f"{c['counts']['REF']} | {c['streams_identical']} | "
            f"{c['legal_ok']} |")
    lines.append(f"\nall_ok={report['all_ok']} mode={report['mode']}")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys

    if "--table" in sys.argv:
        print(oracle_table())
    else:
        main(full="--full" in sys.argv)
