"""Shared benchmark machinery: timing, CSV output, artifact format."""
from __future__ import annotations

import csv
import os
import time

from repro.core import get_stage, sweep
from repro.core.mess import DEFAULT_PACES, WRITE_MIXES

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "reports",
                       "benchmarks")

#: run.py defaults — CI-speed; pass --full for paper-resolution sweeps
FAST_PACES = (1, 4, 12, 24, 48, 64)
FAST_MIXES = (0, 16, 32)
FAST_WINDOWS = dict(windows=48, warmup=16)


def preset_suffix(preset: str) -> str:
    """Artifact/metric-name suffix: empty for the paper's DDR4 device."""
    return "" if preset == "ddr4_2666" else f"_{preset}"


def run_sweep(stage: str, *, full: bool = False,
              preset: str = "ddr4_2666"):
    kw = {} if full else FAST_WINDOWS
    cfg = get_stage(stage, preset=preset, **kw)
    t0 = time.perf_counter()
    res = sweep(cfg,
                paces=DEFAULT_PACES if full else FAST_PACES,
                write_mixes=WRITE_MIXES if full else FAST_MIXES)
    wall = time.perf_counter() - t0
    n_points = len(res.paces) * len(res.write_mixes)
    return res, wall / n_points * 1e6     # us per simulated point


def write_csv(res, name: str):
    """Artifact-format bandwidth_latency.csv per stage."""
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.csv")
    rows = res.to_rows()
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)
    return path


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
