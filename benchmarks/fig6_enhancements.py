"""Fig. 6: further enhancements — address mapping, NOC, prefetchers.

6a (stage 05): the Skylake XOR mapping restores the read/write-mix
    gradient the simple mapping hides.
6b (stage 06): the 2-D mesh NOC adds ~10 ns across the range.
6c (stage 07): stride prefetchers add traffic -> higher saturated
    latency (paper: up to +37 ns).
"""
from __future__ import annotations

from benchmarks.util import emit, run_sweep, write_csv


def main(full: bool = False):
    res4, us4 = run_sweep("04-model-correct", full=full)
    res5, us5 = run_sweep("05-addrmap", full=full)
    res6, us6 = run_sweep("06-noc", full=full)
    res7, us7 = run_sweep("07-prefetch", full=full)
    for r, n in ((res5, "fig6a_addrmap"), (res6, "fig6b_noc"),
                 (res7, "fig6c_prefetch")):
        write_csv(r, n)

    # 6a: gradient = read-only saturation bw over most-write mix
    grad_simple = float(res4.sim_bw[0].max() / res4.sim_bw[-1].max())
    grad_xor = float(res5.sim_bw[0].max() / res5.sim_bw[-1].max())
    emit("fig6a.rw_gradient_simple", us5,
         f"{grad_simple:.2f}x (flat = gradient hidden)")
    emit("fig6a.rw_gradient_xor", us5,
         f"{grad_xor:.2f}x (actual system: ~1.2x, gradient restored)")

    # 6b: NOC latency delta at low load
    delta = float(res6.app_lat[0, 0] - res5.app_lat[0, 0])
    emit("fig6b.noc_delta_ns", us6, f"+{delta:.1f} (paper: +10)")

    # 6c: prefetcher saturated-latency delta
    d7 = float(res7.app_lat[0].max() - res6.app_lat[0].max())
    emit("fig6c.prefetch_saturated_delta_ns", us7,
         f"{d7:+.1f} (paper: up to +37)")
    return res5, res6, res7


if __name__ == "__main__":
    main()
