"""LLM inference serving replayed through the memory presets.

The "serve the planet" benchmark: for each device preset, lower a
model x arrival-rate grid of continuous-batching serving scenarios
(`repro.traces.llm`) into traces and replay them through the platform
in ONE batched invocation — the scenario axis is stacked and sharded
by `replay_suite`'s `sharded_vmap`, so every cell of a preset shares
one compiled program.

Reported per cell (the application + interface perspectives):

* ``req_p50/p95/p99_ms`` — per-request arrival-to-completion latency
  under memory contention (`request_latencies_ms`: scheduler steps
  priced at the replayed service rate).
* ``if_p50/p95/p99_ns``  — memory interface latency percentiles from
  the in-kernel telemetry histograms (`repro.obs.hist_percentiles`).
* ``runtime_ms``, ``gbps`` — schedule service time and achieved
  traffic bandwidth.

Artifact: ``reports/benchmarks/BENCH_serve.json`` (schema
``serving-v1``).  Read it with `docs/SERVING.md`'s walkthrough.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import numpy as np

from benchmarks.util import OUT_DIR, emit
from repro.configs.registry import get_config
from repro.core import get_stage
from repro.obs import hist_percentiles
from repro.traces import (ServeScenario, lower_scenario,
                          request_latencies_ms, replay_suite,
                          stack_traces)

#: smoke grid (CI): 2 models x 2 presets x 2 arrival rates
SMOKE_MODELS = ("tinyllama-1.1b", "qwen2-72b")
SMOKE_PRESETS = ("ddr5_4800", "hbm2e")
SMOKE_RATES = (0.25, 1.0)

FULL_MODELS = ("tinyllama-1.1b", "qwen2-72b", "arctic-480b",
               "zamba2-2.7b")
FULL_PRESETS = ("ddr4_2666", "ddr5_4800", "hbm2e")
FULL_RATES = (0.25, 0.5, 1.0)

STAGE = "10-delay-buffer"
QS = (0.5, 0.95, 0.99)


def _stage_cfg(preset: str, *, windows: int, telemetry: bool = True):
    """Serving replay runs MSHR-hot: full event budget (the same
    contract as the trace-replay cells of the weave golden grid)."""
    cfg = get_stage(STAGE, preset=preset, windows=windows,
                    warmup=max(2, windows // 3), telemetry=telemetry)
    return dataclasses.replace(
        cfg, weave_events=cfg.clock().ticks_per_window_static)


def cell_percentiles(out: dict, a: int) -> dict:
    """Interface-latency percentiles for stacked-trace row ``a``."""
    hist = np.asarray(out["tele_hist_if_ps"][a])
    ps = hist_percentiles(hist, QS)
    return {f"if_p{int(q * 100)}_ns": float(v) / 1e3
            for q, v in zip(QS, ps)}


def serve_grid(models, presets, rates, *, arrival: str = "poisson",
               n_requests: int = 12, n_slots: int = 4,
               windows: int = 6) -> list[dict]:
    """Lower + replay the grid; one batched replay per preset."""
    cells = []
    scns = [ServeScenario(model=get_config(m), arrival=arrival, rate=r,
                          n_requests=n_requests, n_slots=n_slots,
                          seed=17 * i)
            for i, (m, r) in enumerate(
                (m, r) for m in models for r in rates)]
    lowered = [lower_scenario(s) for s in scns]
    batch = stack_traces([tr for tr, _, _ in lowered])
    for preset in presets:
        cfg = _stage_cfg(preset, windows=windows)
        t0 = time.perf_counter()
        out = replay_suite(cfg, batch)
        wall = time.perf_counter() - t0
        for a, (scn, (tr, sched, info)) in enumerate(zip(scns, lowered)):
            rt = float(out["runtime_ms"][a])
            lat = request_latencies_ms(sched, info, rt)
            p50, p95, p99 = np.percentile(lat, [50, 95, 99])
            cell = dict(
                model=scn.model.name, preset=preset,
                arrival=scn.arrival, rate=scn.rate,
                n_requests=scn.n_requests, n_slots=scn.n_slots,
                steps=int(sched.steps), accesses=int(info["accesses"]),
                shard=int(info["shard"]),
                bytes_modeled=int(info["bytes_modeled"]),
                runtime_ms=rt,
                gbps=info["bytes_modeled"] / info["shard"] / (rt * 1e6),
                req_p50_ms=float(p50), req_p95_ms=float(p95),
                req_p99_ms=float(p99),
                wall_s_cell=wall / len(scns),
                **cell_percentiles(out, a))
            cells.append(cell)
    return cells


def main(full: bool = False, **kw):
    models = FULL_MODELS if full else SMOKE_MODELS
    presets = FULL_PRESETS if full else SMOKE_PRESETS
    rates = FULL_RATES if full else SMOKE_RATES
    n_requests = 24 if full else 12
    windows = 12 if full else 6
    cells = serve_grid(models, presets, rates, n_requests=n_requests,
                       windows=windows)
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump(dict(schema="serving-v1", stage=STAGE,
                       models=list(models), presets=list(presets),
                       rates=list(rates), cells=cells), f, indent=1)
    for c in cells:
        emit(f"serve_{c['model']}_{c['preset']}_r{c['rate']}",
             c["wall_s_cell"] * 1e6,
             f"req_p50={c['req_p50_ms']:.3f}ms "
             f"req_p99={c['req_p99_ms']:.3f}ms "
             f"if_p99={c['if_p99_ns']:.0f}ns "
             f"bw={c['gbps']:.1f}GB/s")
    print(f"wrote {path} ({len(cells)} cells)")
    return cells


if __name__ == "__main__":
    main()
